//! Property-based tests (custom harness — util::proptest) over the
//! pure-Rust substrates: fp8 codecs, scaling policy, sharding,
//! collectives, JSON, f16, corpus determinism.

use fp8_trainer::analysis::correlation::channel_correlations;
use fp8_trainer::coordinator::allreduce::{
    allreduce_mean, clip_factor, global_norm, tree_reduce_sum,
};
use fp8_trainer::coordinator::folding::fold_scales;
use fp8_trainer::data::corpus::{Corpus, CorpusConfig};
use fp8_trainer::fp8::{self, E4M3, E5M2};
use fp8_trainer::optimizer::ShardLayout;
use fp8_trainer::scaling::{AmaxHistory, Policy, ScaleDecision};
use fp8_trainer::serving::{channel_scales, swiglu_products};
use fp8_trainer::util::json::Json;
use fp8_trainer::util::proptest::{gen, Prop};
use fp8_trainer::util::prng::Rng;
use fp8_trainer::util::{bf16_round, f16_bits_to_f32, f32_to_f16_bits};

#[test]
fn prop_fp8_qdq_idempotent() {
    Prop::new(2048).check("fp8-qdq-idempotent", gen::f32_any, |&x| {
        for fmt in [E4M3, E5M2] {
            let q1 = fp8::qdq(fmt, x);
            let q2 = fp8::qdq(fmt, q1);
            if !(q1.to_bits() == q2.to_bits() || (q1.is_nan() && q2.is_nan())) {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_fp8_qdq_error_within_half_ulp() {
    Prop::new(2048).check(
        "fp8-qdq-half-ulp",
        |r| gen::f32_finite(r, -400.0, 400.0),
        |&x| {
            let q = fp8::qdq(E4M3, x);
            let exp = x.abs().max(E4M3.min_normal()).log2().floor();
            let ulp = (2f32.powf(exp) * 2f32.powi(-3)).max(E4M3.min_subnormal());
            (q - x).abs() <= ulp / 2.0 + 1e-12
        },
    );
}

#[test]
fn prop_fp8_encode_monotone() {
    // decode(encode(·)) must be monotone non-decreasing
    Prop::new(512).check(
        "fp8-monotone",
        |r| {
            let a = gen::f32_finite(r, -500.0, 500.0);
            let b = gen::f32_finite(r, -500.0, 500.0);
            (a.min(b), a.max(b))
        },
        |&(lo, hi)| {
            fp8::qdq(E4M3, lo.clamp(-448.0, 448.0)) <= fp8::qdq(E4M3, hi.clamp(-448.0, 448.0))
        },
    );
}

#[test]
fn prop_pack_unpack_bounded_error() {
    Prop::new(200).check(
        "pack-roundtrip",
        |r| gen::vec_f32(r, 512, -10.0, 10.0),
        |xs| {
            for fmt in [E4M3, E5M2] {
                let (bytes, scale) = fp8::pack_scaled(fmt, xs);
                if bytes.len() != xs.len() {
                    return false;
                }
                let mut out = Vec::new();
                fp8::unpack_scaled(fmt, &bytes, scale, &mut out);
                let step = 2f32.powi(-(fmt.man_bits() as i32));
                for (&x, &y) in xs.iter().zip(&out) {
                    if (x - y).abs() > x.abs() * step + fmt.min_subnormal() / scale {
                        return false;
                    }
                }
            }
            true
        },
    );
}

#[test]
fn prop_compute_scale_invariants() {
    Prop::new(1024).check(
        "scale-invariants",
        |r| 2f32.powf(gen::f32_finite(r, -30.0, 30.0)),
        |&amax| {
            for fmt in [E4M3, E5M2] {
                let s = fp8::compute_scale(fmt, amax);
                if !(s > 0.0 && s.is_finite()) {
                    return false;
                }
                if amax * s > fmt.max() * 1.000001 {
                    return false; // never overflow the format
                }
            }
            true
        },
    );
}

#[test]
fn prop_scaling_policy_covers_history() {
    Prop::new(300).check(
        "policy-covers-history",
        |r| gen::vec_f32(r, 32, 1e-6, 1e4),
        |amaxes| {
            let mut h = AmaxHistory::new(amaxes.len());
            for &a in amaxes {
                h.push(a);
            }
            match Policy::default().decide(E4M3, &h) {
                ScaleDecision::Set(s) => h.max() * s <= E4M3.max() * 1.000001,
                ScaleDecision::Keep => false,
            }
        },
    );
}

#[test]
fn prop_shards_partition() {
    Prop::new(500).check(
        "shards-partition",
        |r| (gen::usize_in(r, 1, 100_000), gen::usize_in(r, 1, 64)),
        |&(total, w)| {
            let l = ShardLayout::new(total, w);
            let mut covered = 0usize;
            let mut expect_off = 0usize;
            for &(off, len) in &l.shards {
                if off != expect_off {
                    return false;
                }
                covered += len;
                expect_off = off + len;
            }
            covered == total && l.shards.len() == w
        },
    );
}

#[test]
fn prop_chunk_aligned_shards_partition_on_the_grid() {
    Prop::new(500).check(
        "chunk-aligned-shards",
        |r| {
            (
                gen::usize_in(r, 0, 1_000_000),
                gen::usize_in(r, 1, 64),
                gen::usize_in(r, 1, 70_000),
            )
        },
        |&(total, w, chunk)| {
            let l = ShardLayout::chunk_aligned(total, w, chunk);
            let mut expect_off = 0usize;
            let mut covered = 0usize;
            for &(off, len) in &l.shards {
                // contiguous; boundaries on the grid except the empty
                // trailing shards a ragged final chunk leaves at `total`
                if off != expect_off || (off % chunk != 0 && off != total) {
                    return false;
                }
                covered += len;
                expect_off = off + len;
            }
            if covered != total || l.shards.len() != w {
                return false;
            }
            // every element's owner is the shard containing it
            for (w_idx, &(off, len)) in l.shards.iter().enumerate() {
                if len > 0 && (l.owner_of(off) != w_idx || l.owner_of(off + len - 1) != w_idx)
                {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn prop_bucket_schedule_partitions_on_the_adam_chunk_grid() {
    // ISSUE-6: bucket boundaries must land on Adam-chunk multiples for
    // adversarial bucket_bytes — smaller than one chunk (rounds up to
    // exactly one chunk) and larger than the whole model (one bucket)
    // included — because chunk-grid starts are what make per-bucket
    // FP8 grids and Adam scalars identical to the whole-buffer pass.
    use fp8_trainer::coordinator::BucketSchedule;
    Prop::new(500).check(
        "bucket-schedule-grid",
        |r| {
            (
                gen::usize_in(r, 0, 2_000_000),
                gen::usize_in(r, 1, 1 << 31), // bytes: sub-chunk .. way past the model
                gen::usize_in(r, 1, 300_000),
            )
        },
        |&(total, bucket_bytes, chunk)| {
            let s = BucketSchedule::new(total, bucket_bytes, chunk);
            let mut expect_off = 0usize;
            for &(off, len) in &s.buckets {
                // contiguous, non-empty, and every bucket START on the
                // absolute chunk grid; every bucket except the last
                // must also END on the grid (ragged tail only at total)
                if off != expect_off || len == 0 || off % chunk != 0 {
                    return false;
                }
                expect_off = off + len;
                if expect_off != total && expect_off % chunk != 0 {
                    return false;
                }
            }
            expect_off == total && s.len() == s.buckets.len()
        },
    );
}

#[test]
fn prop_tree_reduce_equals_sequential() {
    Prop::new(200).check(
        "tree-reduce",
        |r| {
            let w = gen::usize_in(r, 1, 9);
            let n = gen::usize_in(r, 1, 64);
            (0..w)
                .map(|_| (0..n).map(|_| gen::f32_finite(r, -10.0, 10.0)).collect::<Vec<_>>())
                .collect::<Vec<_>>()
        },
        |bufs| {
            let n = bufs[0].len();
            let expect: Vec<f32> =
                (0..n).map(|i| bufs.iter().map(|b| b[i]).sum()).collect();
            let mut work = bufs.clone();
            tree_reduce_sum(&mut work);
            work[0]
                .iter()
                .zip(&expect)
                .all(|(a, b)| (a - b).abs() <= b.abs() * 1e-5 + 1e-5)
        },
    );
}

#[test]
fn prop_allreduce_mean_broadcasts_identically() {
    Prop::new(200).check(
        "allreduce-broadcast",
        |r| {
            let w = gen::usize_in(r, 2, 8);
            (0..w).map(|_| gen::vec_f32(r, 32, -5.0, 5.0)).collect::<Vec<_>>()
        },
        |bufs| {
            let n = bufs[0].len();
            if bufs.iter().any(|b| b.len() != n) {
                // normalize lengths for the generator's sake
                return true;
            }
            let mut work = bufs.clone();
            allreduce_mean(&mut work);
            work.iter().all(|b| b == &work[0])
        },
    );
}

#[test]
fn prop_clip_factor_bounds_norm() {
    Prop::new(500).check(
        "clip-bounds",
        |r| (gen::f32_finite(r, 0.0, 100.0), gen::f32_finite(r, 0.01, 10.0)),
        |&(norm, max)| {
            let c = clip_factor(norm, max);
            norm * c <= max.max(norm.min(max)) * 1.0001 && c <= 1.0
        },
    );
}

#[test]
fn prop_global_norm_scales_linearly() {
    Prop::new(300).check(
        "gnorm-linear",
        |r| (gen::vec_f32(r, 64, -3.0, 3.0), gen::f32_finite(r, 0.1, 4.0)),
        |(v, k)| {
            let scaled: Vec<f32> = v.iter().map(|x| x * k).collect();
            (global_norm(&scaled) - k * global_norm(v)).abs()
                <= global_norm(v) * k * 1e-5 + 1e-6
        },
    );
}

#[test]
fn prop_f16_roundtrip_error() {
    // log-uniform magnitudes so the subnormal range (|x| < 2^-14) is
    // actually exercised — a uniform generator never samples it
    Prop::new(4096).check(
        "f16-roundtrip",
        |r| {
            let mag = 2f32.powf(gen::f32_finite(r, -26.0, 15.9));
            if r.below(2) == 0 {
                mag
            } else {
                -mag
            }
        },
        |&x| {
            let y = f16_bits_to_f32(f32_to_f16_bits(x));
            if x.abs() < 6.2e-5 {
                // subnormal territory: error bounded by half an ulp
                (y - x).abs() <= 5.96e-8 * 0.51
            } else {
                (y - x).abs() <= x.abs() * (1.0 / 1024.0)
            }
        },
    );
}

#[test]
fn prop_bf16_round_is_idempotent_grid() {
    Prop::new(2048).check("bf16-idempotent", gen::f32_any, |&x| {
        if x.is_nan() {
            return bf16_round(x).is_nan();
        }
        let y = bf16_round(x);
        bf16_round(y).to_bits() == y.to_bits()
    });
}

#[test]
fn prop_json_roundtrip_numbers_strings() {
    Prop::new(500).check(
        "json-roundtrip",
        |r| {
            let n = gen::f32_finite(r, -1e9, 1e9) as f64;
            let s: String = (0..gen::usize_in(r, 0, 12))
                .map(|_| char::from_u32(32 + r.below(90) as u32).unwrap_or('x'))
                .collect();
            (n, s)
        },
        |(n, s)| {
            let j = fp8_trainer::util::json::obj(vec![
                ("n", Json::Num(*n)),
                ("s", Json::Str(s.clone())),
            ]);
            match Json::parse(&j.to_string()) {
                Ok(back) => {
                    back.f64_of("n").unwrap() == *n && back.str_of("s").unwrap() == s
                }
                Err(_) => false,
            }
        },
    );
}

#[test]
fn prop_corpus_deterministic_and_in_range() {
    Prop::new(100).check(
        "corpus-determinism",
        |r| (r.next_u64(), gen::usize_in(r, 2, 512), gen::usize_in(r, 0, 4)),
        |&(seed, vocab, order)| {
            let c = Corpus::new(CorpusConfig { vocab, order, skew: 1.2, seed });
            let mut a = Vec::new();
            let mut b = Vec::new();
            c.fill_sequence(&mut Rng::new(seed ^ 1), 64, &mut a);
            c.fill_sequence(&mut Rng::new(seed ^ 1), 64, &mut b);
            a == b && a.iter().all(|&t| (t as usize) < vocab)
        },
    );
}

// ---- tile-wise FP8 GEMM quantizer (gemm::tile) --------------------

/// Random (rows, cols, tile, data) for a tile-quantizer case.
fn gen_tile_matrix(r: &mut Rng, lo: f32, hi: f32) -> (usize, usize, usize, Vec<f32>) {
    let rows = gen::usize_in(r, 1, 12);
    let cols = gen::usize_in(r, 1, 12);
    let tile = gen::usize_in(r, 1, 6);
    let data = (0..rows * cols).map(|_| gen::f32_finite(r, lo, hi)).collect();
    (rows, cols, tile, data)
}

#[test]
fn prop_tile_scales_are_pow2_chosen_by_the_documented_rule() {
    use fp8_trainer::gemm::TileQuant;
    Prop::new(500).check(
        "tile-scale-rule",
        |r| gen_tile_matrix(r, -100.0, 100.0),
        |(rows, cols, tile, data)| {
            for fmt in [E4M3, E5M2] {
                let q = TileQuant::quantize(fmt, *tile, data, *rows, *cols);
                for (&s, &a) in q.scales.iter().zip(&q.amaxes) {
                    // every scale is a normal power of two …
                    if !(s > 0.0 && s.is_finite() && (s.to_bits() & 0x007f_ffff) == 0) {
                        return false;
                    }
                    // … exactly the one compute_scale picks from the
                    // tile's finite amax, and it never overflows
                    if s.to_bits() != fp8::compute_scale(fmt, a).to_bits()
                        || a * s > fmt.max() * 1.000001
                    {
                        return false;
                    }
                }
            }
            true
        },
    );
}

#[test]
fn prop_tile_qdq_lands_on_grid_and_stays_there() {
    // every finite value's representative is a fixed point of the tile
    // grid: a second QDQ pass changes no bit (the trainer relies on
    // this — re-quantizing already-gridded weights/grads is a no-op)
    use fp8_trainer::gemm::qdq_tilewise;
    Prop::new(500).check(
        "tile-qdq-on-grid",
        |r| gen_tile_matrix(r, -500.0, 500.0),
        |(rows, cols, tile, data)| {
            for fmt in [E4M3, E5M2] {
                let mut once = data.clone();
                qdq_tilewise(fmt, *tile, &mut once, *rows, *cols);
                let mut twice = once.clone();
                qdq_tilewise(fmt, *tile, &mut twice, *rows, *cols);
                if !once.iter().zip(&twice).all(|(a, b)| a.to_bits() == b.to_bits()) {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn prop_zero_denormal_and_outlier_tiles_pick_documented_scales() {
    use fp8_trainer::gemm::TileQuant;
    Prop::new(500).check(
        "tile-scale-edge-cases",
        |r| {
            let tile = gen::usize_in(r, 2, 6);
            let outlier = gen::f32_finite(r, 50.0, 5000.0);
            let tiny = 2f32.powi(-(gen::usize_in(r, 100, 126) as i32));
            (tile, outlier, tiny)
        },
        |&(tile, outlier, tiny)| {
            for fmt in [E4M3, E5M2] {
                // all-zero tile: amax clamps to 1e-12, the documented
                // fallback — scale is finite, elements decode to ±0
                let z = TileQuant::quantize(fmt, tile, &vec![0.0; tile * tile], tile, tile);
                if z.scales[0].to_bits() != fp8::compute_scale(fmt, 0.0).to_bits() {
                    return false;
                }
                if (0..tile).any(|i| (0..tile).any(|j| z.get(i, j) != 0.0)) {
                    return false;
                }
                // denormal-amax tile: scale stays finite (exp2i clamp)
                let d = TileQuant::quantize(fmt, tile, &vec![tiny; tile * tile], tile, tile);
                if !d.scales[0].is_finite() || d.scales[0] <= 0.0 {
                    return false;
                }
                // single outlier owns its tile's scale
                let mut v = vec![0.25f32; tile * tile];
                v[1] = outlier;
                let o = TileQuant::quantize(fmt, tile, &v, tile, tile);
                if o.scales[0].to_bits() != fp8::compute_scale(fmt, outlier).to_bits() {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn prop_nonfinite_values_stay_inside_their_tile() {
    // a NaN (or Inf) must propagate through its own tile's codes
    // without perturbing any other tile — and without perturbing even
    // its *own* tile's scale, because the amax scan is finite-only
    use fp8_trainer::gemm::TileQuant;
    Prop::new(500).check(
        "tile-nonfinite-isolation",
        |r| {
            let (rows, cols, tile, data) = gen_tile_matrix(r, -10.0, 10.0);
            let pos = gen::usize_in(r, 0, rows * cols - 1);
            let poison = if r.below(2) == 0 { f32::NAN } else { f32::INFINITY };
            (rows, cols, tile, data, pos, poison)
        },
        |(rows, cols, tile, data, pos, poison)| {
            for fmt in [E4M3, E5M2] {
                let clean = TileQuant::quantize(fmt, *tile, data, *rows, *cols);
                let mut poisoned_data = data.clone();
                poisoned_data[*pos] = *poison;
                let q = TileQuant::quantize(fmt, *tile, &poisoned_data, *rows, *cols);
                // scales identical everywhere — non-finites are
                // invisible to the finite-only amax
                if !q.scales.iter().zip(&clean.scales).all(|(a, b)| a.to_bits() == b.to_bits())
                {
                    return false;
                }
                for i in 0..*rows {
                    for j in 0..*cols {
                        let (a, b) = (q.get(i, j), clean.get(i, j));
                        if i * cols + j == *pos {
                            // the poisoned element decodes non-finite:
                            // NaN stays NaN; Inf keeps E5M2's ±inf and
                            // becomes NaN under E4M3 (no inf code)
                            if a.is_finite() {
                                return false;
                            }
                        } else if a.to_bits() != b.to_bits() {
                            return false;
                        }
                    }
                }
            }
            true
        },
    );
}

#[test]
fn prop_pow2_rescale_commutes_with_the_tile_grid() {
    // uniform pow2 scaling commutes bit-exactly with tile QDQ inside
    // the safe exponent band: QDQ(x·2^e) == QDQ(x)·2^e. This is the
    // property that lets Smooth-SwiGLU's pow2 per-channel scales fold
    // through the quantization grid without changing any code (see
    // examples/smooth_swiglu_inference.rs and gemm::scale_pow2).
    use fp8_trainer::gemm::{qdq_tilewise, scale_pow2};
    Prop::new(500).check(
        "tile-pow2-commutation",
        |r| {
            let (rows, cols, tile, mut data) = gen_tile_matrix(r, -8.0, 8.0);
            // keep magnitudes off the denormal floor so 2^e stays exact
            for x in data.iter_mut() {
                if x.abs() < 1e-3 {
                    *x = 1e-3_f32.copysign(*x);
                }
            }
            let e = gen::usize_in(r, 0, 6) as i32 - 3;
            (rows, cols, tile, data, e)
        },
        |(rows, cols, tile, data, e)| {
            for fmt in [E4M3, E5M2] {
                // scale then quantize …
                let mut a = data.clone();
                scale_pow2(&mut a, *e);
                qdq_tilewise(fmt, *tile, &mut a, *rows, *cols);
                // … vs quantize then scale
                let mut b = data.clone();
                qdq_tilewise(fmt, *tile, &mut b, *rows, *cols);
                scale_pow2(&mut b, *e);
                if !a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()) {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn prop_correlation_bounded_and_symmetric() {
    Prop::new(200).check(
        "corr-bounds",
        |r| {
            let d = gen::usize_in(r, 2, 16);
            let f = gen::usize_in(r, 1, 8);
            let w1 = gen::vec_f32(r, 1, -1.0, 1.0)
                .into_iter()
                .cycle()
                .take(d * f)
                .map(|_| gen::f32_finite(r, -2.0, 2.0))
                .collect::<Vec<_>>();
            let w2: Vec<f32> = (0..d * f).map(|_| gen::f32_finite(r, -2.0, 2.0)).collect();
            (d, f, w1, w2)
        },
        |(d, f, w1, w2)| {
            let s12 = channel_correlations(w1, w2, *d, *f);
            let s21 = channel_correlations(w2, w1, *d, *f);
            s12.iter().zip(&s21).all(|(a, b)| {
                a.cosine.abs() <= 1.0 + 1e-5 && (a.cosine - b.cosine).abs() < 1e-5
            })
        },
    );
}

// --- Smooth-SwiGLU folding (paper §4.4), promoted from the
// --- smooth_swiglu_inference example into asserted properties.

/// Folding pow2 scales into w1 (w̃1 = s·w1) makes the plain SwiGLU
/// product **bitwise** equal to the per-channel-scaled product: pow2
/// multiplication commutes with f32 rounding, so s·(a1·a2·σ(a2)) ==
/// (s·a1)·a2·σ(a2) down to the last mantissa bit.
#[test]
fn prop_swiglu_fold_bit_exact_for_pow2_scales() {
    Prop::new(200).check(
        "swiglu-fold-bits",
        |r| {
            let d = gen::usize_in(r, 2, 24);
            let f = gen::usize_in(r, 1, 12);
            let t = gen::usize_in(r, 1, 8);
            let w1: Vec<f32> = (0..d * f).map(|_| gen::f32_finite(r, -2.0, 2.0)).collect();
            let w2: Vec<f32> = (0..d * f).map(|_| gen::f32_finite(r, -2.0, 2.0)).collect();
            let w3: Vec<f32> = (0..f * d).map(|_| gen::f32_finite(r, -2.0, 2.0)).collect();
            let xs: Vec<f32> = (0..t * d).map(|_| gen::f32_finite(r, -2.0, 2.0)).collect();
            let fmt = if r.next_u64() % 2 == 0 { E4M3 } else { E5M2 };
            (d, f, t, w1, w2, w3, xs, fmt)
        },
        |(d, f, t, w1, w2, w3, xs, fmt)| {
            let h = swiglu_products(xs, w1, w2, *t, *d, *f);
            // pow2 commutation holds except through the subnormal floor;
            // random moderate inputs essentially never land there, but a
            // property test must not flake on the measure-zero tail
            if h.iter().any(|x| x.abs() != 0.0 && x.abs() < 1e-20) {
                return true;
            }
            let s = channel_scales(*fmt, &h, *t, *f);
            let mut w1f = w1.clone();
            let mut w3f = w3.clone();
            fold_scales(&mut w1f, &mut w3f, std::slice::from_ref(&s), *d, *f).unwrap();
            let hf = swiglu_products(xs, &w1f, w2, *t, *d, *f);
            for ti in 0..*t {
                for j in 0..*f {
                    let want = h[ti * f + j] * s[j];
                    let got = hf[ti * f + j];
                    if want.to_bits() != got.to_bits() && !(want.is_nan() && got.is_nan()) {
                        return false;
                    }
                }
            }
            true
        },
    );
}

/// The example's outlier-channel payload, asserted: one aligned large
/// channel (the quadratic blow-up) gets a taming scale < 1, and the
/// folded product still matches the scaled product bit-for-bit.
#[test]
fn swiglu_fold_bit_exact_with_outlier_channel() {
    let (d, f, n_tokens) = (32, 16, 64);
    let mut rng = Rng::new(42);
    let mut w1 = vec![0.0f32; d * f];
    let mut w2 = vec![0.0f32; d * f];
    let mut w3 = vec![0.0f32; f * d];
    rng.fill_normal(&mut w1, 0.4);
    rng.fill_normal(&mut w2, 0.4);
    rng.fill_normal(&mut w3, 0.4);
    for i in 0..d {
        let a = w2[i * f + 3] * 20.0; // aligned + large
        w1[i * f + 3] = a;
        w2[i * f + 3] = a;
    }
    let mut xs = vec![0.0f32; n_tokens * d];
    rng.fill_normal(&mut xs, 1.0);

    let h = swiglu_products(&xs, &w1, &w2, n_tokens, d, f);
    let s = channel_scales(E4M3, &h, n_tokens, f);
    assert!(s[3] < 1.0, "the outlier channel must get a taming scale, got {}", s[3]);
    assert!(s.iter().all(|&v| v > 0.0 && (v.to_bits() & 0x007f_ffff) == 0), "pow2 scales");

    let mut w1f = w1.clone();
    let mut w3f = w3.clone();
    fold_scales(&mut w1f, &mut w3f, std::slice::from_ref(&s), d, f).unwrap();
    let hf = swiglu_products(&xs, &w1f, &w2, n_tokens, d, f);
    for t in 0..n_tokens {
        for j in 0..f {
            assert_eq!(
                (h[t * f + j] * s[j]).to_bits(),
                hf[t * f + j].to_bits(),
                "fold mismatch at token {t} channel {j}"
            );
        }
    }
}

/// NaN payloads propagate identically through both paths: a NaN input
/// lane poisons its token's products in the folded form exactly where
/// it poisons the scaled form.
#[test]
fn swiglu_fold_propagates_nan_payloads() {
    let (d, f, t) = (4, 3, 2);
    let mut w1 = vec![0.5f32; d * f];
    let w2 = vec![0.25f32; d * f];
    let mut w3 = vec![1.0f32; f * d];
    let mut xs = vec![1.0f32; t * d];
    xs[0] = f32::NAN; // token 0 poisoned, token 1 clean

    let h = swiglu_products(&xs, &w1, &w2, t, d, f);
    assert!(h[..f].iter().all(|x| x.is_nan()), "token 0 products must be NaN");
    assert!(h[f..].iter().all(|x| x.is_finite()), "token 1 must be untouched");

    let s = vec![0.5f32, 4.0, 1.0];
    fold_scales(&mut w1, &mut w3, std::slice::from_ref(&s), d, f).unwrap();
    let hf = swiglu_products(&xs, &w1, &w2, t, d, f);
    for (k, (&got, &base)) in hf.iter().zip(&h).enumerate() {
        let want = base * s[k % f];
        assert!(
            got.to_bits() == want.to_bits() || (got.is_nan() && want.is_nan()),
            "lane {k}: folded {got:e} vs scaled {want:e}"
        );
    }
}

/// Signed zero survives the fold: a −0.0 SwiGLU product stays −0.0 in
/// the folded path (pow2 scaling never flips the sign bit).
#[test]
fn swiglu_fold_preserves_signed_zero() {
    let (d, f, t) = (1, 1, 1);
    // a1 = +0.0, a2 = −1.0 → h = (+0.0 · −1.0)·σ = −0.0
    let mut w1 = vec![0.0f32];
    let w2 = vec![-1.0f32];
    let mut w3 = vec![1.0f32];
    let xs = vec![1.0f32];
    let h = swiglu_products(&xs, &w1, &w2, t, d, f);
    assert_eq!(h[0].to_bits(), (-0.0f32).to_bits(), "payload must be a negative zero");

    let s = vec![4.0f32];
    fold_scales(&mut w1, &mut w3, std::slice::from_ref(&s), d, f).unwrap();
    let hf = swiglu_products(&xs, &w1, &w2, t, d, f);
    assert_eq!(hf[0].to_bits(), (h[0] * s[0]).to_bits());
    assert_eq!(hf[0].to_bits(), (-0.0f32).to_bits(), "fold must not launder −0.0 into +0.0");
}
