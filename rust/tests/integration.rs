//! Integration tests over real artifacts (require `make artifacts`).
//! These exercise the full HLO-text → PJRT → coordinator path on the
//! tiny preset, including cross-layer agreement between the Rust fp8
//! codec and the JAX-side quantization inside the artifacts.

use std::sync::Arc;

use fp8_trainer::config::TrainConfig;
use fp8_trainer::coordinator::Trainer;
use fp8_trainer::fp8::{self, E4M3, E5M2};
use fp8_trainer::runtime::{HostTensor, Runtime};

/// One shared PJRT client for the whole test binary: the TFRT CPU
/// client does not tolerate repeated create/destroy cycles in one
/// process (observed SIGSEGV on teardown with per-test clients).
fn runtime() -> Arc<Runtime> {
    static RT: std::sync::OnceLock<Arc<Runtime>> = std::sync::OnceLock::new();
    RT.get_or_init(|| Arc::new(Runtime::new("artifacts").expect("run `make artifacts` first")))
        .clone()
}

fn tiny_cfg(recipe: &str) -> TrainConfig {
    TrainConfig {
        size: "tiny".into(),
        recipe: recipe.into(),
        steps: 4,
        warmup_steps: 1,
        lr: 1e-3,
        out_dir: format!("runs/it_{recipe}"),
        ..Default::default()
    }
}

#[test]
fn grad_artifact_loss_is_sane() {
    let rt = runtime();
    let mut t = Trainer::new(rt, tiny_cfg("fp8_full")).unwrap();
    let o = t.step().unwrap();
    // ln(256) = 5.545; random init should be within a quarter nat
    assert!((o.loss - 5.545).abs() < 0.25, "loss {}", o.loss);
    assert!(o.grad_norm > 0.0 && o.grad_norm.is_finite());
    assert_eq!(o.monitor.len(), 2); // tiny has 2 layers
}

#[test]
fn scales_adapt_after_first_step() {
    let rt = runtime();
    let mut t = Trainer::new(rt, tiny_cfg("fp8_full")).unwrap();
    let before = t.scale_mgr.scales().to_vec();
    assert!(before.iter().all(|&s| s == 1.0), "cold start at scale 1");
    t.step().unwrap();
    let after = t.scale_mgr.scales().to_vec();
    assert!(after.iter().any(|&s| s != 1.0), "delayed scaling must engage");
    // activation scales should be > 1 (amax << 448 at init)
    assert!(after[0] > 1.0, "x_attn scale {}", after[0]);
}

#[test]
fn training_reduces_loss_on_tiny() {
    let rt = runtime();
    let mut cfg = tiny_cfg("fp8_full");
    cfg.steps = 60;
    cfg.warmup_steps = 6;
    cfg.lr = 3e-3;
    let mut t = Trainer::new(rt, cfg).unwrap();
    let first = t.step().unwrap().loss;
    let mut last = first;
    for _ in 1..60 {
        last = t.step().unwrap().loss;
    }
    assert!(last < first - 0.1, "loss {first} -> {last} must improve");
    assert!(!t.detector.has_diverged());
}

#[test]
fn bf16_and_fp8_agree_at_init() {
    let rt = runtime();
    let l_bf16 = Trainer::new(rt.clone(), tiny_cfg("bf16")).unwrap().step().unwrap().loss;
    let l_fp8 = Trainer::new(rt, tiny_cfg("fp8_full")).unwrap().step().unwrap().loss;
    assert!((l_bf16 - l_fp8).abs() < 0.05, "{l_bf16} vs {l_fp8}");
}

#[test]
fn adam_artifact_matches_rust_fp8_grids() {
    // run the fp8-moment adam artifact once and verify every output
    // moment value is a fixed point of the *Rust* codec at the
    // per-chunk pow2 scale — cross-language grid agreement.
    let rt = runtime();
    let art = rt.load("adam_e4m3_e5m2_c262144").unwrap();
    let chunk = art.manifest.chunk;
    let n = chunk;
    let p = HostTensor::from_f32(&[n], (0..n).map(|i| (i as f32 * 0.001).sin()).collect());
    let m = HostTensor::zeros(&[n]);
    let v = HostTensor::zeros(&[n]);
    let g = HostTensor::from_f32(&[n], (0..n).map(|i| 0.01 * ((i as f32) * 0.37).cos()).collect());
    let scalars = HostTensor::from_f32(&[4], vec![1e-3, 0.0, 1.0, 1.0]);
    let out = art.run(&[p, m, v, g, scalars]).unwrap();
    for (t, fmt) in [(&out[1], E4M3), (&out[2], E5M2)] {
        let vals = t.f32s();
        let amax = vals.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        let s = fp8::compute_scale(fmt, amax);
        for &x in vals.iter().step_by(97) {
            let q = fmt.decode(fmt.encode(x * s)) / s;
            assert!(
                (q - x).abs() <= x.abs() * 1e-6 + 1e-12,
                "{fmt:?}: {x} not on grid at scale {s}"
            );
        }
    }
}

#[test]
fn eval_artifact_reports_chance_accuracy_at_init() {
    let rt = runtime();
    let t = Trainer::new(rt, tiny_cfg("bf16")).unwrap();
    let (ppl, acc) = t.eval("bf16", 2).unwrap();
    assert!((ppl - 256.0).abs() < 80.0, "ppl {ppl} should be near vocab size");
    assert!(acc < 0.1, "accuracy {acc} should be near chance");
}

#[test]
fn dp_workers_change_nothing_but_throughput_shape() {
    // 2-worker data parallelism must produce finite, comparable loss
    // (different data order, same distribution) and identical tensors
    // across reruns (determinism).
    let rt = runtime();
    let mut cfg = tiny_cfg("fp8_full");
    cfg.dp_workers = 2;
    cfg.grad_accum = 2;
    let mut a = Trainer::new(rt.clone(), cfg.clone()).unwrap();
    let mut b = Trainer::new(rt, cfg).unwrap();
    for _ in 0..3 {
        let oa = a.step().unwrap();
        let ob = b.step().unwrap();
        assert_eq!(oa.loss.to_bits(), ob.loss.to_bits(), "bitwise reproducible");
    }
    assert_eq!(
        a.params.tensors[0].f32s(),
        b.params.tensors[0].f32s(),
        "parameter state reproducible under DP"
    );
}

#[test]
fn parallel_workers_bit_identical_to_serial() {
    // the scoped-thread worker fan-out must be invisible to the
    // numbers: same loss, same grad-norm, same amax history (and thus
    // scales), same parameters as the inline serial schedule.
    let rt = runtime();
    let mut cfg = tiny_cfg("fp8_full");
    cfg.dp_workers = 4;
    cfg.grad_accum = 2;
    let mut par = Trainer::new(rt.clone(), cfg.clone()).unwrap();
    let mut ser = Trainer::new(rt, cfg).unwrap();
    ser.force_serial_workers = true;
    for _ in 0..3 {
        let a = par.step().unwrap();
        let b = ser.step().unwrap();
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss must be bit-identical");
        assert_eq!(
            a.grad_norm.to_bits(),
            b.grad_norm.to_bits(),
            "grad-norm must be bit-identical"
        );
        for (ma, mb) in a.monitor.iter().zip(&b.monitor) {
            for k in 0..3 {
                assert_eq!(ma[k].to_bits(), mb[k].to_bits(), "monitor must match");
            }
        }
    }
    assert_eq!(par.scale_mgr.scales(), ser.scale_mgr.scales(), "amax/scale history");
    for (ta, tb) in par.params.tensors.iter().zip(&ser.params.tensors) {
        assert_eq!(ta.f32s(), tb.f32s(), "parameter state must be bit-identical");
    }
    let (pm, pv) = par.moments_flat();
    let (sm, sv) = ser.moments_flat();
    assert_eq!(pm, sm, "first moment");
    assert_eq!(pv, sv, "second moment");
}

#[test]
fn logical_streams_decouple_batch_identity_from_workers() {
    // the elastic-resharding foundation: the loss curve is a function
    // of the LOGICAL stream plan (grad_streams × stream_pods), not of
    // the physical worker/pod count. A 4-stream plan run on 4 workers
    // and the same plan squeezed onto 2 workers / 1 pod must produce
    // bit-identical everything — this is what lets `campaign resume
    // --reshard` continue a W=4 campaign on whatever fleet is left.
    let rt = runtime();
    let mut full = tiny_cfg("fp8_full");
    full.dp_workers = 4;
    full.pods = 2;
    full.grad_accum = 2;
    let mut shrunk = full.clone();
    shrunk.dp_workers = 2;
    shrunk.pods = 1;
    shrunk.grad_streams = 4; // pin the logical plan to the full shape
    shrunk.stream_pods = 2;
    let mut a = Trainer::new(rt.clone(), full).unwrap();
    let mut b = Trainer::new(rt, shrunk).unwrap();
    for _ in 0..3 {
        let oa = a.step().unwrap();
        let ob = b.step().unwrap();
        assert_eq!(oa.loss.to_bits(), ob.loss.to_bits(), "loss must not see the fleet size");
        assert_eq!(oa.grad_norm.to_bits(), ob.grad_norm.to_bits(), "grad norm");
        for (ma, mb) in oa.monitor.iter().zip(&ob.monitor) {
            for k in 0..3 {
                assert_eq!(ma[k].to_bits(), mb[k].to_bits(), "monitor must match");
            }
        }
    }
    assert_eq!(a.scale_mgr.scales(), b.scale_mgr.scales(), "amax/scale history");
    for (ta, tb) in a.params.tensors.iter().zip(&b.params.tensors) {
        assert_eq!(ta.f32s(), tb.f32s(), "params across physical topologies");
    }
    let (am, av) = a.moments_flat();
    let (bm, bv) = b.moments_flat();
    assert_eq!(am, bm, "first moment");
    assert_eq!(av, bv, "second moment");
}

#[test]
fn sharded_fp8_path_bit_identical_to_f32_resident_baseline() {
    // the pinned ISSUE-4 equivalence: with collective_fp8_intra =
    // false (default), the ZeRO-1 sharded step with exact-FP8-packed moment
    // shards must reproduce the replicated-style f32-resident
    // schedule bit-for-bit at every worker count — packing between
    // steps is exact-verified, so sharding + packing is invisible to
    // the numbers.
    let rt = runtime();
    for dp in [1usize, 2, 4] {
        let mut cfg = tiny_cfg("fp8_full");
        cfg.dp_workers = dp;
        cfg.grad_accum = 2;
        let mut packed = Trainer::new(rt.clone(), cfg.clone()).unwrap();
        cfg.pack_moments = false; // keep every shard resident f32
        let mut raw = Trainer::new(rt.clone(), cfg).unwrap();
        for _ in 0..3 {
            let a = packed.step().unwrap();
            let b = raw.step().unwrap();
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "dp={dp}: loss");
            assert_eq!(a.grad_norm.to_bits(), b.grad_norm.to_bits(), "dp={dp}: grad norm");
        }
        for (ta, tb) in packed.params.tensors.iter().zip(&raw.params.tensors) {
            assert_eq!(ta.f32s(), tb.f32s(), "dp={dp}: params");
        }
        let (pm, pv) = packed.moments_flat();
        let (rm, rv) = raw.moments_flat();
        assert_eq!(pm, rm, "dp={dp}: first moment");
        assert_eq!(pv, rv, "dp={dp}: second moment");
        // memory accounting is reported either way (the (W-1)/W floor
        // itself is asserted in benches/perf_hotpath.rs over sizes
        // with many chunks per worker; `tiny` may fit in one chunk)
        assert!(packed.moment_bytes_per_worker() <= packed.params.total_elems() * 8);
    }
}

#[test]
fn fp8_collective_is_reproducible_and_trains() {
    // the compressed collective changes the gradient bits (that's the
    // point) but must stay bit-deterministic across identical runs and
    // keep the loss sane; the wire accounting must show the ~4x
    // compression.
    let rt = runtime();
    let mut cfg = tiny_cfg("fp8_full");
    cfg.dp_workers = 2;
    cfg.collective_fp8_intra = true;
    let mut a = Trainer::new(rt.clone(), cfg.clone()).unwrap();
    let mut b = Trainer::new(rt, cfg).unwrap();
    for _ in 0..3 {
        let oa = a.step().unwrap();
        let ob = b.step().unwrap();
        assert_eq!(oa.loss.to_bits(), ob.loss.to_bits(), "fp8 collective must be deterministic");
        assert!(oa.loss.is_finite() && (oa.loss - 5.545).abs() < 0.5, "loss {}", oa.loss);
    }
    let stats = a.collective_stats();
    assert!(
        stats.wire_bytes() > 0 && stats.wire_ratio() < 0.3,
        "ratio {}",
        stats.wire_ratio()
    );
    let (ma, _) = a.moments_flat();
    let (mb, _) = b.moments_flat();
    assert_eq!(ma, mb, "moment state must be reproducible under the fp8 collective");
}

#[test]
fn two_level_f32_collective_is_invisible_to_training() {
    // ISSUE-5: pods = 2 with compression off on both levels must
    // reproduce the flat pods = 1 run bit-for-bit through real
    // training steps (power-of-two pod size: the flat binary tree
    // decomposes exactly at pod boundaries). Topology then only moves
    // bytes between levels, never additions.
    let rt = runtime();
    let mut cfg = tiny_cfg("fp8_full");
    cfg.dp_workers = 4;
    cfg.collective_fp8_inter = false; // all-f32 two-level
    let mut flat = Trainer::new(rt.clone(), cfg.clone()).unwrap();
    cfg.pods = 2;
    let mut hier = Trainer::new(rt, cfg).unwrap();
    for _ in 0..3 {
        let oa = flat.step().unwrap();
        let ob = hier.step().unwrap();
        assert_eq!(oa.loss.to_bits(), ob.loss.to_bits(), "loss must be topology-invariant");
        assert_eq!(oa.grad_norm.to_bits(), ob.grad_norm.to_bits(), "grad norm");
    }
    for (ta, tb) in flat.params.tensors.iter().zip(&hier.params.tensors) {
        assert_eq!(ta.f32s(), tb.f32s(), "params must be bit-identical across topologies");
    }
    // but the wire accounting must differ: the hierarchical run
    // reports an inter level, the flat run does not
    assert_eq!(flat.collective_stats().inter.total(), 0);
    assert!(hier.collective_stats().inter.total() > 0);
    assert_eq!(
        flat.collective_stats().wire_bytes(),
        flat.collective_stats().wire_bytes_f32()
    );
}

#[test]
fn probe_artifact_exposes_preactivations() {
    let rt = runtime();
    let art = rt.load("probe_s1m_l0").unwrap();
    let man = &art.manifest;
    let mut inputs: Vec<HostTensor> = man
        .params
        .iter()
        .map(|p| {
            if p.init_std < 0.0 {
                HostTensor::from_f32(&p.shape, vec![1.0; p.numel()])
            } else {
                let mut rng = fp8_trainer::util::prng::Rng::new(5);
                let mut d = vec![0.0f32; p.numel()];
                rng.fill_normal(&mut d, p.init_std);
                HostTensor::from_f32(&p.shape, d)
            }
        })
        .collect();
    inputs.push(HostTensor::from_f32(&[man.n_scales], vec![1.0; man.n_scales]));
    inputs.push(HostTensor::from_i32(
        &[man.batch, 129],
        vec![3; man.batch * 129],
    ));
    let out = art.run(&inputs).unwrap();
    let d_ff = man.raw.usize_of("d_ff").unwrap();
    assert_eq!(out[0].shape(), &[man.batch * 128, d_ff]);
    assert_eq!(out[1].shape(), &[man.batch * 128, d_ff]);
    assert!(out[0].f32s().iter().all(|x| x.is_finite()));
}

#[test]
fn overlapped_bit_identical_to_phased_across_matrix() {
    // ISSUE-6 tentpole gate: the bucketed overlapped pipeline must be
    // bit-identical to the phased reference across worker counts,
    // topologies and wire compression. Everything that could drift —
    // FP8 grids, reduce order, norm fold order, Adam chunk scalars —
    // is pinned here through real training steps.
    let rt = runtime();
    for dp in [1usize, 2, 4] {
        for pods in [1usize, 2] {
            if pods > dp || dp % pods != 0 {
                continue;
            }
            for fp8_wire in [false, true] {
                let tag = format!("dp={dp} pods={pods} fp8_wire={fp8_wire}");
                let mut cfg = tiny_cfg("fp8_full");
                cfg.dp_workers = dp;
                cfg.grad_accum = 2;
                cfg.pods = pods;
                cfg.collective_fp8_intra = fp8_wire;
                cfg.collective_fp8_inter = fp8_wire;
                let mut ov = Trainer::new(rt.clone(), cfg.clone()).unwrap();
                let mut ph = Trainer::new(rt.clone(), cfg).unwrap();
                ph.force_phased_step = true;
                for _ in 0..3 {
                    let a = ov.step().unwrap();
                    let b = ph.step().unwrap();
                    assert!(a.timers.overlapped && !b.timers.overlapped, "{tag}: dispatch");
                    assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{tag}: loss");
                    assert_eq!(a.grad_norm.to_bits(), b.grad_norm.to_bits(), "{tag}: grad norm");
                    for (ma, mb) in a.monitor.iter().zip(&b.monitor) {
                        for k in 0..3 {
                            assert_eq!(ma[k].to_bits(), mb[k].to_bits(), "{tag}: monitor");
                        }
                    }
                }
                assert_eq!(ov.scale_mgr.scales(), ph.scale_mgr.scales(), "{tag}: scales");
                for (ta, tb) in ov.params.tensors.iter().zip(&ph.params.tensors) {
                    assert_eq!(ta.f32s(), tb.f32s(), "{tag}: params");
                }
                let (am, av) = ov.moments_flat();
                let (bm, bv) = ph.moments_flat();
                assert_eq!(am, bm, "{tag}: first moment");
                assert_eq!(av, bv, "{tag}: second moment");
            }
        }
    }
}

#[test]
fn overlapped_multi_bucket_matches_phased_on_s1m() {
    // `tiny` fits one Adam chunk, so the matrix above runs a single
    // bucket. s1m with a 1 MiB bucket spans several — this is the test
    // that exercises the cross-bucket norm straddle, the
    // double-buffered collective scratch and per-bucket Adam dispatch.
    let rt = runtime();
    let mut cfg = TrainConfig {
        size: "s1m".into(),
        recipe: "fp8_full".into(),
        steps: 4,
        warmup_steps: 1,
        lr: 1e-3,
        dp_workers: 2,
        out_dir: "runs/it_overlap_s1m".into(),
        ..Default::default()
    };
    cfg.bucket_bytes = 1 << 20;
    let mut ov = Trainer::new(rt.clone(), cfg.clone()).unwrap();
    let mut ph = Trainer::new(rt, cfg).unwrap();
    ph.force_phased_step = true;
    assert!(ov.bucket_schedule().len() > 1, "s1m must span multiple buckets");
    for _ in 0..2 {
        let a = ov.step().unwrap();
        let b = ph.step().unwrap();
        assert_eq!(a.timers.buckets, ov.bucket_schedule().len(), "timers report the schedule");
        assert_eq!(b.timers.buckets, 1, "phased is one monolithic bucket");
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss");
        assert_eq!(a.grad_norm.to_bits(), b.grad_norm.to_bits(), "grad norm");
    }
    for (ta, tb) in ov.params.tensors.iter().zip(&ph.params.tensors) {
        assert_eq!(ta.f32s(), tb.f32s(), "params");
    }
    let (am, av) = ov.moments_flat();
    let (bm, bv) = ph.moments_flat();
    assert_eq!(am, bm, "first moment");
    assert_eq!(av, bv, "second moment");
}

#[test]
fn adversarial_bucket_sizes_are_bit_invariant() {
    // ISSUE-6: bucket_bytes smaller than one Adam chunk (rounds up to
    // exactly one chunk per bucket) vs larger than the whole model
    // (one monolithic bucket) must produce the same bits — the
    // partition only reshapes the pipeline, never the arithmetic.
    let rt = runtime();
    let base = TrainConfig {
        size: "s1m".into(),
        recipe: "fp8_full".into(),
        steps: 4,
        warmup_steps: 1,
        lr: 1e-3,
        dp_workers: 2,
        out_dir: "runs/it_bucket_adv".into(),
        ..Default::default()
    };
    let mut small = base.clone();
    small.bucket_bytes = 1;
    let mut huge = base;
    huge.bucket_bytes = 1 << 30;
    let mut a = Trainer::new(rt.clone(), small).unwrap();
    let mut b = Trainer::new(rt, huge).unwrap();
    assert!(a.bucket_schedule().len() > 1, "1-byte buckets round to one chunk each");
    assert_eq!(b.bucket_schedule().len(), 1, "over-sized bucket covers the model");
    for _ in 0..2 {
        let oa = a.step().unwrap();
        let ob = b.step().unwrap();
        assert_eq!(oa.loss.to_bits(), ob.loss.to_bits(), "loss");
        assert_eq!(oa.grad_norm.to_bits(), ob.grad_norm.to_bits(), "grad norm");
    }
    for (ta, tb) in a.params.tensors.iter().zip(&b.params.tensors) {
        assert_eq!(ta.f32s(), tb.f32s(), "params");
    }
}

#[test]
fn grad_worker_panic_poisons_and_refuses_next_step() {
    // ISSUE-6 satellite: an injected panic inside a grad worker must
    // be contained (no process abort), surface as an Err pointing the
    // operator at the latest snapshot, poison the trainer, and make
    // the next step refuse — in both schedules.
    let rt = runtime();
    for phased in [false, true] {
        let mut cfg = tiny_cfg("fp8_full");
        cfg.dp_workers = 2;
        let mut t = Trainer::new(rt.clone(), cfg).unwrap();
        t.force_phased_step = phased;
        t.step().unwrap(); // one healthy step first
        t.inject_worker_panic = Some(1);
        let err = t.step().expect_err("injected panic must fail the step");
        let msg = format!("{err:#}");
        assert!(msg.contains("panicked"), "phased={phased}: {msg}");
        assert!(msg.contains("snapshot"), "phased={phased}: {msg}");
        assert!(t.is_poisoned(), "phased={phased}: trainer must be poisoned");
        t.inject_worker_panic = None;
        let err2 = t.step().expect_err("poisoned trainer must refuse to step");
        assert!(format!("{err2:#}").contains("inconsistent"), "phased={phased}: {err2:#}");
    }
}

#[test]
fn checkpoint_roundtrip_through_trainer_state() {
    use fp8_trainer::checkpoint::{Checkpoint, Dtype, Writer};
    use fp8_trainer::util::json::{obj, Json};

    let rt = runtime();
    let mut t = Trainer::new(rt, tiny_cfg("fp8_full")).unwrap();
    for _ in 0..3 {
        t.step().unwrap();
    }
    let dir = std::env::temp_dir().join("fp8_it_ckpt");
    let path = dir.join("t.ckpt");
    let mut w = Writer::new(&obj(vec![("step", Json::Num(3.0))]));
    for (spec, tensor) in t.params.specs.iter().zip(&t.params.tensors) {
        w.tensor(&spec.name, Dtype::F16, tensor.f32s());
    }
    let (m_gather, v_gather) = t.moments_flat();
    w.tensor("adam.m", Dtype::E4M3, &m_gather);
    w.tensor("adam.v", Dtype::E5M2, &v_gather);
    w.finish(&path).unwrap();

    let c = Checkpoint::load(&path).unwrap();
    assert_eq!(c.meta.f64_of("step").unwrap(), 3.0);
    // f16 master: relative error < 2^-10 on normals, one subnormal ulp
    // in absolute terms below the f16 normal range
    let w1 = c.tensor("w1").unwrap();
    let (idx, _) = t.params.index_of("w1").unwrap();
    for (a, b) in t.params.tensors[idx].f32s().iter().zip(w1) {
        assert!(
            (a - b).abs() <= a.abs() * 1.1e-3 + 6.2e-8,
            "f16 roundtrip: {a} vs {b} (err {})",
            (a - b).abs()
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}
