//! End-to-end serving conformance suite: real-socket round-trips
//! against the `serving::` HTTP layer on an ephemeral port.
//!
//! The headline property (paper §4.4): folded-FP8 serving is
//! **bit-identical** to the unfolded scaled reference — same artifact,
//! two servers, identical tokens and per-step logits CRCs over the
//! wire. Around it: healthz/metrics, deterministic generation, batched
//! concurrent clients vs serial, streaming chunk reassembly, typed
//! 4xx refusals for malformed/oversized requests, and export refusing
//! on fold mismatch or payload corruption.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;

use fp8_trainer::fp8::{Fp8Format, E4M3, E5M2};
use fp8_trainer::runtime::manifest::ModelDims;
use fp8_trainer::serving::export::synth_state_for;
use fp8_trainer::serving::{
    export_state, probe_tokens_for, serve, Engine, ExportOptions, ExportReport, ServeConfig,
    ServeMode, ServerHandle,
};
use fp8_trainer::util::json::Json;
use fp8_trainer::util::proptest::Prop;
use fp8_trainer::util::prng::Rng;

// ---------------------------------------------------------------- helpers

/// Small ragged dims (not a preset — exercises the explicit-dims
/// export path and keeps the suite fast).
fn dims_small() -> ModelDims {
    ModelDims { vocab: 48, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 12, seq_len: 24 }
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fp8_serving_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn export_small(tag: &str, seed: u64, fmt: Fp8Format) -> (PathBuf, ExportReport) {
    let dir = fresh_dir(tag);
    let dims = dims_small();
    let st = synth_state_for("custom", &dims, seed);
    let opts =
        ExportOptions { fmt, probe_tokens: 6, dims: Some(dims), ..Default::default() };
    let path = dir.join("model.fp8m");
    let report = export_state(&st, &path, &opts).unwrap();
    (path, report)
}

fn serve_small(path: &std::path::Path, mode: ServeMode, batch: usize) -> ServerHandle {
    let engine = Engine::load(path, mode).unwrap();
    let cfg = ServeConfig { batch, batch_wait_ms: 30, ..ServeConfig::default() };
    serve(engine, &cfg).unwrap()
}

/// Raw HTTP/1.1 round-trip: write the request, read to EOF (the server
/// closes per response), parse status + body (chunk-decoding when the
/// response is chunked).
fn http(addr: SocketAddr, req: &[u8]) -> (u16, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(req).unwrap();
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).unwrap();
    parse_http(&raw)
}

fn get(addr: SocketAddr, path: &str) -> (u16, String) {
    http(
        addr,
        format!("GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n").as_bytes(),
    )
}

fn post_json(addr: SocketAddr, path: &str, body: &str) -> (u16, String) {
    http(
        addr,
        format!(
            "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        )
        .as_bytes(),
    )
}

fn parse_http(raw: &[u8]) -> (u16, String) {
    let pos = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .expect("response has a head/body split");
    let head = std::str::from_utf8(&raw[..pos]).unwrap();
    let body = &raw[pos + 4..];
    let status: u16 = head
        .lines()
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|s| s.parse().ok())
        .expect("status line");
    let chunked = head
        .lines()
        .any(|l| l.to_ascii_lowercase().replace(' ', "") == "transfer-encoding:chunked");
    let body = if chunked { decode_chunked(body) } else { body.to_vec() };
    (status, String::from_utf8(body).unwrap())
}

fn decode_chunked(mut body: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    loop {
        let eol = body.windows(2).position(|w| w == b"\r\n").expect("chunk size line");
        let size = usize::from_str_radix(
            std::str::from_utf8(&body[..eol]).unwrap().trim(),
            16,
        )
        .expect("hex chunk size");
        body = &body[eol + 2..];
        if size == 0 {
            return out;
        }
        out.extend_from_slice(&body[..size]);
        body = &body[size + 2..]; // skip trailing \r\n
    }
}

fn gen_body(prompt: &[usize], max_new: usize, stream: bool) -> String {
    let ids: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    format!(
        "{{\"prompt\":[{}],\"max_new\":{max_new},\"stream\":{stream}}}",
        ids.join(",")
    )
}

fn tokens_and_crcs(body: &str) -> (Vec<usize>, Vec<u64>) {
    let j = Json::parse(body).unwrap();
    let toks = j
        .get("tokens")
        .and_then(|t| t.as_arr())
        .unwrap()
        .iter()
        .map(|t| t.as_usize().unwrap())
        .collect();
    let crcs = j
        .get("logits_crcs")
        .and_then(|t| t.as_arr())
        .unwrap()
        .iter()
        .map(|t| t.as_f64().unwrap() as u64)
        .collect();
    (toks, crcs)
}

// ------------------------------------------------------------------ tests

#[test]
fn healthz_and_metrics_over_socket() {
    let (path, report) = export_small("healthz", 11, E4M3);
    let server = serve_small(&path, ServeMode::Folded, 4);
    let addr = server.addr();

    let (status, body) = get(addr, "/v1/healthz");
    assert_eq!(status, 200, "{body}");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.str_or("status", ""), "ok");
    let model = j.get("model").unwrap();
    assert_eq!(model.str_or("size", ""), "custom");
    assert_eq!(model.str_or("mode", ""), "folded");
    assert_eq!(
        j.usize_of("resident_fp8_bytes").unwrap(),
        report.resident_fp8_bytes,
        "healthz reports the measured FP8 residency"
    );

    let (_, _) = post_json(addr, "/v1/generate", &gen_body(&[1, 2, 3], 2, false));
    let (status, text) = get(addr, "/v1/metrics");
    assert_eq!(status, 200);
    for needle in [
        "# TYPE fp8_serve_requests_total counter",
        "fp8_serve_batches_total",
        "fp8_serve_generated_tokens_total",
        "fp8_serve_resident_fp8_bytes",
        "fp8_serve_model_info{size=\"custom\"",
    ] {
        assert!(text.contains(needle), "metrics missing {needle}:\n{text}");
    }
    server.shutdown();
}

#[test]
fn generate_is_deterministic_and_matches_in_process() {
    let (path, _) = export_small("determinism", 12, E4M3);
    let server = serve_small(&path, ServeMode::Folded, 4);
    let addr = server.addr();
    let prompt = [3usize, 14, 15, 9, 2];

    let (s1, b1) = post_json(addr, "/v1/generate", &gen_body(&prompt, 6, false));
    let (s2, b2) = post_json(addr, "/v1/generate", &gen_body(&prompt, 6, false));
    assert_eq!((s1, s2), (200, 200), "{b1}\n{b2}");
    let (t1, c1) = tokens_and_crcs(&b1);
    let (t2, c2) = tokens_and_crcs(&b2);
    assert_eq!(t1, t2, "served generation must be deterministic");
    assert_eq!(c1, c2);
    assert_eq!(t1.len(), 6);

    // the socket layer adds nothing: in-process generation agrees
    let mut engine = Engine::load(&path, ServeMode::Folded).unwrap();
    let direct = engine.generate_batch(&[prompt.to_vec()], &[6], |_, _, _, _| {}).unwrap();
    assert_eq!(direct[0].tokens, t1);
    assert_eq!(direct[0].crcs.iter().map(|&c| c as u64).collect::<Vec<_>>(), c1);
    server.shutdown();
}

#[test]
fn concurrent_batched_clients_match_serial() {
    let (path, _) = export_small("batched", 13, E4M3);
    let server = serve_small(&path, ServeMode::Folded, 4);
    let addr = server.addr();
    let prompts: Vec<Vec<usize>> =
        vec![vec![1, 2, 3], vec![40, 7], vec![5, 6, 7, 8, 9], vec![21]];

    // serial: each request rides its own batch
    let serial: Vec<(Vec<usize>, Vec<u64>)> = prompts
        .iter()
        .map(|p| {
            let (s, b) = post_json(addr, "/v1/generate", &gen_body(p, 5, false));
            assert_eq!(s, 200, "{b}");
            tokens_and_crcs(&b)
        })
        .collect();

    // concurrent: the batcher may coalesce any subset of these
    let handles: Vec<_> = prompts
        .iter()
        .cloned()
        .map(|p| {
            std::thread::spawn(move || {
                let (s, b) = post_json(addr, "/v1/generate", &gen_body(&p, 5, false));
                assert_eq!(s, 200, "{b}");
                tokens_and_crcs(&b)
            })
        })
        .collect();
    let concurrent: Vec<(Vec<usize>, Vec<u64>)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();

    assert_eq!(
        serial, concurrent,
        "batched concurrent serving must be token- and bit-identical to serial"
    );
    server.shutdown();
}

#[test]
fn streaming_chunks_reassemble_to_the_nonstreaming_result() {
    let (path, _) = export_small("streaming", 14, E4M3);
    let server = serve_small(&path, ServeMode::Folded, 2);
    let addr = server.addr();
    let prompt = [8usize, 9, 10];

    let (s_plain, b_plain) = post_json(addr, "/v1/generate", &gen_body(&prompt, 5, false));
    assert_eq!(s_plain, 200, "{b_plain}");
    let (tokens, crcs) = tokens_and_crcs(&b_plain);

    let (s_stream, b_stream) = post_json(addr, "/v1/generate", &gen_body(&prompt, 5, true));
    assert_eq!(s_stream, 200, "{b_stream}");
    let lines: Vec<&str> = b_stream.lines().filter(|l| !l.is_empty()).collect();
    assert_eq!(lines.len(), tokens.len() + 1, "one event per token + summary: {b_stream}");
    for (step, line) in lines[..tokens.len()].iter().enumerate() {
        let e = Json::parse(line).unwrap();
        assert_eq!(e.usize_of("step").unwrap(), step);
        assert_eq!(e.usize_of("token").unwrap(), tokens[step], "stream diverges at {step}");
        assert_eq!(e.f64_of("crc").unwrap() as u64, crcs[step]);
    }
    let done = Json::parse(lines[tokens.len()]).unwrap();
    assert_eq!(done.get("done").and_then(|d| d.as_bool()), Some(true));
    let (final_tokens, final_crcs) = tokens_and_crcs(lines[tokens.len()]);
    assert_eq!(final_tokens, tokens, "summary line must equal the non-streaming result");
    assert_eq!(final_crcs, crcs);
    server.shutdown();
}

#[test]
fn malformed_and_oversized_requests_get_typed_refusals() {
    let (path, _) = export_small("refusals", 15, E4M3);
    let engine = Engine::load(&path, ServeMode::Folded).unwrap();
    let cfg = ServeConfig { max_body_bytes: 256, ..ServeConfig::default() };
    let server = serve(engine, &cfg).unwrap();
    let addr = server.addr();

    let expect = |status: u16, kind: &str, (got, body): (u16, String)| {
        assert_eq!(got, status, "{body}");
        let j = Json::parse(&body).unwrap_or_else(|e| panic!("refusal not JSON ({e}): {body}"));
        assert_eq!(j.str_or("error", ""), kind, "{body}");
        assert_eq!(j.usize_of("status").unwrap(), status as usize);
    };

    expect(400, "malformed_request", post_json(addr, "/v1/generate", "{not json"));
    expect(400, "malformed_request", post_json(addr, "/v1/generate", r#"{"prompt":"hi"}"#));
    expect(
        400,
        "malformed_request",
        post_json(addr, "/v1/generate", r#"{"prompt":[1,2.5]}"#),
    );
    expect(400, "malformed_request", post_json(addr, "/v1/generate", r#"{"prompt":[]}"#));
    expect(400, "bad_token", post_json(addr, "/v1/generate", r#"{"prompt":[1,999]}"#));
    let long: Vec<usize> = (0..30).map(|i| i % 40).collect();
    expect(400, "prompt_too_long", post_json(addr, "/v1/generate", &gen_body(&long, 1, false)));
    expect(404, "not_found", get(addr, "/nope"));
    expect(405, "method_not_allowed", get(addr, "/v1/generate"));

    // oversized body: refused from the declared Content-Length, and the
    // refusal names the limit it broke
    let big = gen_body(&(0..40).map(|i| i % 40).collect::<Vec<_>>(), 1, false) + &" ".repeat(300);
    let (status, body) = post_json(addr, "/v1/generate", &big);
    assert_eq!(status, 413, "{body}");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.str_or("error", ""), "oversized_body");
    assert!(
        j.str_or("detail", "").contains("serve_max_body_bytes = 256"),
        "refusal must name the limit: {body}"
    );

    // no Content-Length at all
    let (status, _) = http(
        addr,
        b"POST /v1/generate HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n",
    );
    assert_eq!(status, 411);

    // none of that killed the server
    let (status, _) = get(addr, "/v1/healthz");
    assert_eq!(status, 200, "server must survive refused requests");
    let (status, body) = post_json(addr, "/v1/generate", &gen_body(&[1, 2], 2, false));
    assert_eq!(status, 200, "{body}");
    server.shutdown();
}

#[test]
fn folded_serving_is_bit_identical_to_scaled_reference_over_socket() {
    let (path, _) = export_small("foldgate", 16, E4M3);
    let folded = serve_small(&path, ServeMode::Folded, 4);
    let reference = serve_small(&path, ServeMode::ScaledReference, 4);

    for prompt in [vec![1usize, 2, 3, 4], vec![47, 0, 13], vec![9]] {
        let body = gen_body(&prompt, 8, false);
        let (sf, bf) = post_json(folded.addr(), "/v1/generate", &body);
        let (sr, br) = post_json(reference.addr(), "/v1/generate", &body);
        assert_eq!((sf, sr), (200, 200), "{bf}\n{br}");
        let (tf, cf) = tokens_and_crcs(&bf);
        let (tr, cr) = tokens_and_crcs(&br);
        assert_eq!(tf, tr, "folded vs reference tokens diverged for {prompt:?}");
        assert_eq!(
            cf, cr,
            "folded vs reference logits CRCs diverged for {prompt:?} — \
             the fold is not bit-exact end to end"
        );
    }
    folded.shutdown();
    reference.shutdown();
}

#[test]
fn export_refuses_on_fold_mismatch_and_writes_nothing() {
    let dir = fresh_dir("foldrefuse");
    let dims = dims_small();
    let st = synth_state_for("custom", &dims, 17);
    let opts = ExportOptions {
        probe_tokens: 6,
        dims: Some(dims),
        corrupt_fold_for_test: true,
        ..Default::default()
    };
    let path = dir.join("model.fp8m");
    let err = export_state(&st, &path, &opts).unwrap_err().to_string();
    assert!(err.contains("fold mismatch"), "got: {err}");
    assert!(err.contains("refusing to export"), "got: {err}");
    assert!(!path.exists(), "a refused export must not leave an artifact behind");
}

#[test]
fn flipped_payload_bit_trips_the_crc_refusal() {
    let (path, _) = export_small("crc", 18, E5M2);
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01;
    std::fs::write(&path, &bytes).unwrap();
    let err = Engine::load(&path, ServeMode::Folded).unwrap_err().to_string();
    assert!(err.contains("checksum mismatch"), "got: {err}");
}

#[test]
fn prop_export_roundtrip_reproduces_forward_bits_across_shapes() {
    // fold → write → load → serve reproduces the probe forward bits
    // across seeds × formats × ragged shapes; then one flipped payload
    // bit must trip the CRC refusal
    Prop::new(6).check(
        "serving-export-roundtrip",
        |r: &mut Rng| {
            let heads = 1 + (r.next_u64() % 2) as usize;
            let hd = if r.next_u64() % 2 == 0 { 4 } else { 8 };
            let dims = ModelDims {
                vocab: if r.next_u64() % 2 == 0 { 17 } else { 33 },
                d_model: heads * hd,
                n_layers: 1 + (r.next_u64() % 2) as usize,
                n_heads: heads,
                d_ff: [5, 7, 12][(r.next_u64() % 3) as usize],
                seq_len: 8 + (r.next_u64() % 5) as usize,
            };
            let fmt = if r.next_u64() % 2 == 0 { E4M3 } else { E5M2 };
            (dims, fmt, r.next_u64())
        },
        |(dims, fmt, seed)| {
            let dir = fresh_dir(&format!("prop_{seed:x}"));
            let st = synth_state_for("custom", dims, *seed);
            let opts = ExportOptions {
                fmt: *fmt,
                probe_tokens: 5,
                dims: Some(dims.clone()),
                ..Default::default()
            };
            let path = dir.join("model.fp8m");
            let report = match export_state(&st, &path, &opts) {
                Ok(r) => r,
                Err(e) => panic!("export failed for {dims:?} {fmt:?} seed {seed}: {e}"),
            };
            // reload and replay the recorded probe: bits must reproduce
            let mut engine = Engine::load(&path, ServeMode::Folded).unwrap();
            let probe = probe_tokens_for(dims, opts.probe_seed, opts.probe_tokens);
            let logits: Vec<f32> =
                engine.forward_full(&probe).unwrap().into_iter().flatten().collect();
            let bytes: Vec<u8> = logits.iter().flat_map(|x| x.to_le_bytes()).collect();
            let crc = fp8_trainer::util::crc32(&bytes);
            if crc != report.probe_crc {
                return false;
            }
            // one flipped payload bit → load refuses
            let mut raw = std::fs::read(&path).unwrap();
            let mid = raw.len() / 2;
            raw[mid] ^= 0x01;
            std::fs::write(&path, &raw).unwrap();
            let refused = Engine::load(&path, ServeMode::Folded)
                .unwrap_err()
                .to_string()
                .contains("checksum mismatch");
            let _ = std::fs::remove_dir_all(&dir);
            refused
        },
    );
}
