//! Campaign subsystem tests.
//!
//! Two tiers:
//! * **artifact-free** — snapshot round-trip property tests (every
//!   field of the extended checkpoint manifest survives save→load
//!   bit-exactly, including amax ring ordering and the PRNG cursor),
//!   retention, journal — these always run;
//! * **artifact-gated** — end-to-end bit-exact resume and the
//!   divergence-injection recovery drill; these skip with a note when
//!   `artifacts/` is absent (run `make artifacts` first), matching the
//!   repo's integration-test convention.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

use fp8_trainer::campaign::journal;
use fp8_trainer::campaign::snapshot::{SnapshotMeta, TrainState};
use fp8_trainer::campaign::store::{list_snapshots, SnapshotStore};
use fp8_trainer::campaign::{Campaign, DirLock, ResumeOptions};
use fp8_trainer::config::TrainConfig;
use fp8_trainer::coordinator::{DetectorState, Trainer};
use fp8_trainer::optimizer::{gather, repartition, MomentStore, ShardLayout};
use fp8_trainer::runtime::Runtime;
use fp8_trainer::scaling::{Policy, ScaleManager, ScaleState};
use fp8_trainer::util::prng::Rng;
use fp8_trainer::util::proptest::Prop;

// ---------------------------------------------------------------- helpers

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

fn vals(rng: &mut Rng, n: usize, span: f32) -> Vec<f32> {
    (0..n).map(|_| ((rng.uniform() as f32) - 0.5) * span).collect()
}

fn synth_state(rng: &mut Rng) -> TrainState {
    let fmts = ["f32", "e4m3", "e5m2"];
    let n_sites = 1 + rng.below(5) as usize;
    let cap = 2 + rng.below(6) as usize;
    let histories: Vec<Vec<f32>> = (0..n_sites)
        .map(|_| {
            let l = rng.below(cap as u64 + 1) as usize;
            (0..l).map(|_| (rng.uniform() as f32) * 100.0 + 1e-3).collect()
        })
        .collect();
    let scales: Vec<f32> =
        (0..n_sites).map(|_| 2f32.powi(rng.below(20) as i32 - 10)).collect();
    let n = 64 + rng.below(200) as usize;
    let mut m = vals(rng, n, 2e-3);
    let mut v = vals(rng, n, 1e-6);
    // specials must survive too (fp8-exact falls back per chunk)
    if n > 10 {
        m[3] = f32::from_bits(0x7fc0_0bad); // NaN with payload
        m[7] = -0.0;
        v[5] = f32::INFINITY;
    }
    TrainState {
        meta: SnapshotMeta {
            step: rng.below(100_000) as usize,
            recipe: "fp8_full".into(),
            size: "tiny".into(),
            // u64 seeds beyond 2^53 pin the string (not f64) encoding
            seed: rng.next_u64() | (1 << 60),
            corpus_seed: rng.next_u64() | (1 << 59),
            dp_workers: 1 + rng.below(8) as usize,
            streams: 1 + rng.below(8) as usize,
            stream_pods: 1 + rng.below(2) as usize,
            grad_accum: 1 + rng.below(4) as usize,
            steps: 1000,
            warmup_steps: 100,
            amax_history: cap,
            margin_pow2: rng.below(4) as i32,
            recoveries: rng.below(5) as usize,
            m_fmt: fmts[rng.below(3) as usize].into(),
            v_fmt: fmts[rng.below(3) as usize].into(),
            // small so the moment vectors span several chunks and the
            // multi-chunk exact-FP8 path is exercised every case
            moment_chunk: 16 + rng.below(48) as usize,
            numerics: format!("synthetic-fingerprint-{}", rng.below(1000)),
            topology: format!(
                "shard=w{};topo=p{};bucket=b{}",
                1 + rng.below(8),
                1 + rng.below(2),
                4096
            ),
        },
        params: vec![
            ("embed".into(), vals(rng, 32 + rng.below(64) as usize, 2.0)),
            ("w1".into(), vals(rng, 32 + rng.below(64) as usize, 0.1)),
            ("w2".into(), vals(rng, 32 + rng.below(64) as usize, 0.1)),
        ],
        m,
        v,
        scale: ScaleState {
            histories,
            scales,
            overflow_events: rng.below(1000) as usize,
        },
        detector: DetectorState {
            ema: f32::from_bits(rng.next_u64() as u32 | 0x3f00_0000) , // arbitrary bits, finite-ish
            warmed: rng.below(2) == 1,
            diverged_at: if rng.below(4) == 0 { Some(rng.below(1000) as usize) } else { None },
        },
    }
}

fn tmp_path(tag: &str) -> std::path::PathBuf {
    static N: AtomicUsize = AtomicUsize::new(0);
    let k = N.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("fp8_campaign_{}_{}_{}", tag, std::process::id(), k))
}

// ------------------------------------------------- artifact-free tier

#[test]
fn prop_snapshot_roundtrip_every_field_bit_exact() {
    let dir = tmp_path("prop");
    std::fs::create_dir_all(&dir).unwrap();
    let counter = AtomicUsize::new(0);
    Prop::new(48).check("snapshot-roundtrip", synth_state, |st| {
        let path = dir.join(format!("s{}.ckpt", counter.fetch_add(1, Ordering::Relaxed)));
        st.save(&path).unwrap();
        let got = TrainState::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        // meta: identity, positions, PRNG cursor, effective policy
        if got.meta != st.meta {
            return false;
        }
        // params by name, bit-exact
        for (name, data) in &st.params {
            match got.params.iter().find(|(n, _)| n == name) {
                Some((_, d)) if bits_eq(d, data) => {}
                _ => return false,
            }
        }
        // moments bit-exact through the fp8-exact / f32 sections,
        // including NaN payloads and signed zeros
        if !bits_eq(&got.m, &st.m) || !bits_eq(&got.v, &st.v) {
            return false;
        }
        // scaling state: ring contents in order, scales, counter
        if got.scale.histories.len() != st.scale.histories.len() {
            return false;
        }
        for (a, b) in got.scale.histories.iter().zip(&st.scale.histories) {
            if !bits_eq(a, b) {
                return false;
            }
        }
        bits_eq(&got.scale.scales, &st.scale.scales)
            && got.scale.overflow_events == st.scale.overflow_events
            && got.detector.ema.to_bits() == st.detector.ema.to_bits()
            && got.detector.warmed == st.detector.warmed
            && got.detector.diverged_at == st.detector.diverged_at
    });
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn snapshot_preserves_ring_ordering_through_a_real_manager() {
    // drive a real ScaleManager past its ring capacity so the buffers
    // have genuinely wrapped, snapshot, restore into a fresh manager,
    // and check the two evolve identically afterwards
    let sites: Vec<String> = vec!["x_attn".into(), "w1".into(), "g_w1".into()];
    let policy = Policy { history_len: 4, ..Default::default() };
    let mut a = ScaleManager::new(2, &sites, policy);
    for k in 0..11 {
        let x = 0.5 + (k as f32 * 0.731).sin().abs();
        a.update(&[x, 2.0 * x, x, 0.1 * x, x, 3.0]);
    }
    let mut st = synth_state(&mut Rng::new(7));
    st.scale = a.export_state();
    let path = tmp_path("ring");
    st.save(&path).unwrap();
    let got = TrainState::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let mut b = ScaleManager::new(2, &sites, policy);
    b.restore_state(&got.scale).unwrap();
    for k in 0..9 {
        let x = 0.2 + k as f32 * 0.37;
        let amax = [x, x, 5.0, x, 0.01, x];
        a.update(&amax);
        b.update(&amax);
        assert!(bits_eq(a.scales(), b.scales()), "diverged at post-restore step {k}");
    }
    assert_eq!(a.overflow_events, b.overflow_events);
}

#[test]
fn store_retention_keeps_newest_k() {
    let dir = tmp_path("retention");
    let store = SnapshotStore::new(&dir, 3).unwrap();
    let mut rng = Rng::new(42);
    for step in [10usize, 20, 30, 40, 50] {
        let mut st = synth_state(&mut rng);
        st.meta.step = step;
        store.save(&st).unwrap();
    }
    let listed = store.list().unwrap();
    let steps: Vec<usize> = listed.iter().map(|&(s, _)| s).collect();
    assert_eq!(steps, vec![30, 40, 50], "keep-last-3 must drop 10 and 20");
    assert_eq!(store.latest().unwrap().unwrap().0, 50);
    // read-only discovery agrees and pruned files are really gone
    assert_eq!(list_snapshots(&dir).unwrap().len(), 3);
    assert!(!store.path_for(10).exists());
    assert!(!store.path_for(20).exists());
    // every survivor is loadable
    for (_, path) in listed {
        TrainState::load(&path).unwrap();
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn snapshot_load_rejects_damage() {
    let path = tmp_path("damage");
    let st = synth_state(&mut Rng::new(3));
    st.save(&path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    bytes.truncate(bytes.len() / 2);
    std::fs::write(&path, &bytes).unwrap();
    assert!(TrainState::load(&path).is_err(), "truncated snapshot must not load");
    std::fs::remove_file(&path).ok();
    // a plain (non-campaign) checkpoint is also rejected by kind
    let plain = tmp_path("plainckpt");
    let mut w = fp8_trainer::checkpoint::Writer::new(&fp8_trainer::util::json::obj(vec![]));
    w.tensor("x", fp8_trainer::checkpoint::Dtype::F32, &[1.0]);
    w.finish(&plain).unwrap();
    assert!(TrainState::load(&plain).is_err(), "kind check must reject");
    std::fs::remove_file(&plain).ok();
}

#[test]
fn prop_reshard_roundtrip_reproduces_original_shard_bytes() {
    // W → W′ → W across worker counts 1..=6, chunk-offset totals, and
    // all three moment stores: re-partitioning the gathered state and
    // coming back must reproduce the ORIGINAL shard bytes (packed
    // digests), not merely close values — the property `campaign
    // resume --reshard` stands on
    struct Case {
        data: Vec<f32>,
        chunk: usize,
        w: usize,
        w2: usize,
        store: MomentStore,
    }
    let gen = |rng: &mut Rng| {
        let chunk = 8 + rng.below(56) as usize;
        let n_chunks = 1 + rng.below(9) as usize;
        let total = chunk * n_chunks + rng.below(chunk as u64) as usize;
        let mut data = vals(rng, total, 2e-3);
        if total > 4 {
            data[1] = f32::from_bits(0x7fc0_0001); // NaN payload
            data[3] = -0.0;
        }
        let store = match rng.below(3) {
            0 => MomentStore::F32,
            1 => MomentStore::Fp8(fp8_trainer::fp8::E4M3),
            _ => MomentStore::Fp8(fp8_trainer::fp8::E5M2),
        };
        Case {
            data,
            chunk,
            w: 1 + rng.below(6) as usize,
            w2: 1 + rng.below(6) as usize,
            store,
        }
    };
    Prop::new(48).check("reshard-roundtrip", gen, |c| {
        let lay_w = ShardLayout::chunk_aligned(c.data.len(), c.w, c.chunk);
        let lay_w2 = ShardLayout::chunk_aligned(c.data.len(), c.w2, c.chunk);
        let mut original = repartition(&c.data, &lay_w, c.store);
        let digests: Vec<u32> = original.iter_mut().map(|s| s.packed_digest()).collect();
        // W → W′: gather and re-partition for the new worker count
        let flat1 = gather(&original);
        if !bits_eq(&flat1, &c.data) {
            return false;
        }
        let prime = repartition(&flat1, &lay_w2, c.store);
        let flat2 = gather(&prime);
        if !bits_eq(&flat2, &c.data) {
            return false;
        }
        // W′ → W: the original shard bytes come back exactly
        let mut back = repartition(&flat2, &lay_w, c.store);
        let digests2: Vec<u32> = back.iter_mut().map(|s| s.packed_digest()).collect();
        digests == digests2
    });
}

#[test]
#[cfg(target_os = "linux")]
fn stale_lock_with_dead_owner_is_reclaimed() {
    let dir = tmp_path("stale_lock");
    std::fs::create_dir_all(&dir).unwrap();
    // pid 999999999 exceeds the kernel's pid_max (4194304): provably
    // no live owner, so acquire must reclaim and remember the pid
    std::fs::write(dir.join("LOCK"), "999999999\n").unwrap();
    let lock = DirLock::acquire(&dir).expect("dead-owner lock must be reclaimed");
    assert_eq!(lock.reclaimed_from(), Some(999_999_999));
    drop(lock);
    assert!(!dir.join("LOCK").exists(), "drop must release the reclaimed lock");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn live_or_garbage_lock_refuses_conservatively() {
    let dir = tmp_path("live_lock");
    std::fs::create_dir_all(&dir).unwrap();
    // our own pid is alive by definition — never reclaimed
    std::fs::write(dir.join("LOCK"), format!("{}\n", std::process::id())).unwrap();
    let err = DirLock::acquire(&dir).unwrap_err().to_string();
    assert!(err.contains("locked"), "live owner must refuse: {err}");
    assert!(dir.join("LOCK").exists(), "refusal must not touch the live lock");
    // garbage contents: no pid to probe, conservative refusal
    std::fs::write(dir.join("LOCK"), "not-a-pid\n").unwrap();
    assert!(DirLock::acquire(&dir).is_err(), "unparsable lock must refuse");
    assert!(dir.join("LOCK").exists());
    std::fs::remove_dir_all(&dir).ok();
}

// ------------------------------------------------ artifact-gated tier

/// One shared PJRT client for the whole test binary (the TFRT CPU
/// client does not tolerate repeated create/destroy in one process),
/// or None on a bare checkout without `artifacts/`.
fn runtime() -> Option<Arc<Runtime>> {
    static RT: OnceLock<Option<Arc<Runtime>>> = OnceLock::new();
    RT.get_or_init(|| Runtime::new("artifacts").ok().map(Arc::new)).clone()
}

macro_rules! need_artifacts {
    () => {
        match runtime() {
            Some(rt) => rt,
            None => {
                eprintln!("skipping: artifacts/ not found (run `make artifacts` first)");
                return;
            }
        }
    };
}

fn tiny_cfg(recipe: &str) -> TrainConfig {
    TrainConfig {
        size: "tiny".into(),
        recipe: recipe.into(),
        steps: 12,
        warmup_steps: 2,
        lr: 1e-3,
        out_dir: "runs/campaign_test".into(),
        ..Default::default()
    }
}

#[test]
fn bit_exact_resume_matches_uninterrupted_run() {
    let rt = need_artifacts!();
    let cfg = tiny_cfg("fp8_full");
    // reference: uninterrupted 12 steps
    let mut a = Trainer::new(rt.clone(), cfg.clone()).unwrap();
    let mut ref_bits = Vec::new();
    for _ in 0..cfg.steps {
        ref_bits.push(a.step().unwrap().loss.to_bits());
    }
    // killed at step 5: capture → save → drop → load → apply → continue
    let mut b = Trainer::new(rt.clone(), cfg.clone()).unwrap();
    let mut got_bits = Vec::new();
    for _ in 0..5 {
        got_bits.push(b.step().unwrap().loss.to_bits());
    }
    let path = tmp_path("trainer_resume");
    TrainState::capture(&b, 0).save(&path).unwrap();
    drop(b);
    let loaded = TrainState::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let mut c = Trainer::new(rt.clone(), cfg.clone()).unwrap();
    loaded.apply_to(&mut c).unwrap();
    assert_eq!(c.step, 5, "resume must land on the kill step");
    for _ in 5..cfg.steps {
        got_bits.push(c.step().unwrap().loss.to_bits());
    }
    assert_eq!(got_bits, ref_bits, "stop+resume must reproduce the loss curve bit-exactly");
    // full state equality at the end, not just the loss
    for (ta, tc) in a.params.tensors.iter().zip(&c.params.tensors) {
        assert!(bits_eq(ta.f32s(), tc.f32s()), "final params must be bit-identical");
    }
    let (am, av) = a.moments_flat();
    let (cm, cv) = c.moments_flat();
    assert!(bits_eq(&am, &cm), "first moment");
    assert!(bits_eq(&av, &cv), "second moment");
    assert!(bits_eq(a.scale_mgr.scales(), c.scale_mgr.scales()), "scales");

    // and a mismatched config must refuse to resume
    let mut other = cfg.clone();
    other.seed ^= 1;
    let mut d = Trainer::new(rt, other).unwrap();
    assert!(loaded.apply_to(&mut d).is_err(), "seed mismatch must be rejected");
}

#[test]
fn campaign_kill_resume_reproduces_uninterrupted_curve() {
    let rt = need_artifacts!();
    let mut cfg = tiny_cfg("fp8_full");
    cfg.steps = 10;
    cfg.snapshot_every = 3;
    cfg.snapshot_keep = 2;
    let base = tmp_path("kill_resume");
    // uninterrupted campaign
    let mut ca = Campaign::new(rt.clone(), cfg.clone(), base.join("a")).unwrap();
    let ra = ca.run().unwrap();
    assert!(ra.completed);
    assert_eq!(ra.losses.len(), 10);
    // same campaign, killed at step 4 then resumed
    let mut cb = Campaign::new(rt.clone(), cfg.clone(), base.join("b")).unwrap();
    cb.stop_after = Some(4);
    let rb1 = cb.run().unwrap();
    assert!(!rb1.completed && rb1.paused);
    assert_eq!(rb1.final_step, 4);
    drop(cb);
    let mut cb2 = Campaign::resume(rt, cfg, base.join("b")).unwrap();
    let rb2 = cb2.run().unwrap();
    assert!(rb2.completed);
    let merged: Vec<(usize, u32)> = rb1
        .losses
        .iter()
        .chain(rb2.losses.iter())
        .map(|&(s, l)| (s, l.to_bits()))
        .collect();
    let reference: Vec<(usize, u32)> =
        ra.losses.iter().map(|&(s, l)| (s, l.to_bits())).collect();
    assert_eq!(merged, reference, "killed+resumed campaign must equal the uninterrupted one");
    for (ta, tb) in ca.trainer.params.tensors.iter().zip(&cb2.trainer.params.tensors) {
        assert!(bits_eq(ta.f32s(), tb.f32s()), "final params must be bit-identical");
    }
    let ev = journal::read(base.join("b").join("journal.jsonl")).unwrap();
    assert_eq!(journal::count(&ev, "pause"), 1);
    assert_eq!(journal::count(&ev, "resume"), 1);
    assert_eq!(journal::count(&ev, "complete"), 1);
    std::fs::remove_dir_all(&base).ok();
}

#[test]
fn campaign_auto_recovers_from_injected_divergence() {
    let rt = need_artifacts!();
    let mut cfg = tiny_cfg("fp8_full");
    cfg.steps = 9;
    cfg.snapshot_every = 3;
    cfg.max_recoveries = 2;
    let dir = tmp_path("recovery_drill");
    let mut c = Campaign::new(rt, cfg, &dir).unwrap();
    c.inject_divergence_at = Some(5);
    let r = c.run().unwrap();
    assert!(r.completed, "the drill must recover and finish");
    assert_eq!(r.final_step, 9);
    assert_eq!(r.recoveries, 1);
    assert!(r.losses.len() > 9, "replayed steps must appear in the honest loss record");
    assert!(r.final_loss.is_finite());
    let ev = journal::read(dir.join("journal.jsonl")).unwrap();
    assert_eq!(journal::count(&ev, "divergence"), 1);
    assert_eq!(journal::count(&ev, "recovery"), 1);
    assert_eq!(journal::count(&ev, "complete"), 1);
    let div = journal::last(&ev, "divergence").unwrap();
    assert_eq!(div.usize_of("step").unwrap(), 5);
    assert_eq!(div.get("injected"), Some(&fp8_trainer::util::json::Json::Bool(true)));
    let rec = journal::last(&ev, "recovery").unwrap();
    // rolled back to the last good periodic snapshot (step 3), and the
    // perturbed policy is on the record: base margin 1 + backoff 1
    assert_eq!(rec.usize_of("rolled_back_to").unwrap(), 3);
    assert_eq!(rec.usize_of("margin_pow2").unwrap(), 2);
    assert_eq!(rec.usize_of("amax_history").unwrap(), 8); // 16 / 2
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn worker_loss_drill_reshard_resumes_bit_exact() {
    // The elastic-resharding drill: a campaign on W=4/pods=2 loses a
    // worker mid-run; `resume --reshard` continues it on W=3/pods=1
    // with a bit-identical loss curve, the reshard journaled, and a
    // stale lock from the "crashed" process reclaimed on the way in.
    let rt = need_artifacts!();
    let mut cfg = tiny_cfg("fp8_full");
    cfg.steps = 10;
    cfg.snapshot_every = 3;
    cfg.dp_workers = 4;
    cfg.pods = 2;
    let base = tmp_path("reshard_drill");
    // reference: uninterrupted campaign on the full fleet
    let mut ca = Campaign::new(rt.clone(), cfg.clone(), base.join("a")).unwrap();
    let ra = ca.run().unwrap();
    assert!(ra.completed);
    // the drill campaign, "killed" at step 4 (orderly pause = the
    // deterministic stand-in for a node loss)
    let mut cb = Campaign::new(rt.clone(), cfg.clone(), base.join("b")).unwrap();
    cb.stop_after = Some(4);
    let rb1 = cb.run().unwrap();
    assert!(rb1.paused);
    drop(cb);

    // one worker gone, pods collapse: W=3 / pods=1
    let mut lost = cfg.clone();
    lost.dp_workers = 3;
    lost.pods = 1;

    // bare resume with the logical plan pinned: numerics match, only
    // topology differs — the refusal must name the flag
    let mut pinned = lost.clone();
    pinned.grad_streams = 4;
    pinned.stream_pods = 2;
    let err =
        Campaign::resume(rt.clone(), pinned, base.join("b")).unwrap_err().to_string();
    assert!(err.contains("--reshard"), "topology refusal must suggest the flag: {err}");
    assert!(err.contains("shard"), "diff must name the changed term: {err}");

    // bare resume with defaulted stream keys: the *effective* logical
    // plan would move with W — a numerics refusal, reshard can't help
    let err2 =
        Campaign::resume(rt.clone(), lost.clone(), base.join("b")).unwrap_err().to_string();
    assert!(err2.contains("numerics"), "moved plan is a numerics refusal: {err2}");

    // a changed numerics term refuses even WITH --reshard
    let mut hot = lost.clone();
    hot.lr *= 2.0;
    let err3 = Campaign::resume_opts(
        rt.clone(),
        hot,
        base.join("b"),
        ResumeOptions { reshard: true },
    )
    .unwrap_err()
    .to_string();
    assert!(err3.contains("numerics"), "reshard must never move numerics: {err3}");

    // plant a dead-owner lock, as a crashed run would leave behind
    #[cfg(target_os = "linux")]
    std::fs::write(base.join("b").join("LOCK"), "999999999\n").unwrap();

    // the real thing: resume --reshard on the shrunken fleet
    let mut cb2 = Campaign::resume_opts(
        rt.clone(),
        lost.clone(),
        base.join("b"),
        ResumeOptions { reshard: true },
    )
    .unwrap();
    assert_eq!(cb2.trainer.cfg.dp_workers, 3);
    assert_eq!(cb2.trainer.cfg.streams(), 4, "adopted logical plan");
    assert_eq!(cb2.trainer.cfg.stream_pod_count(), 2, "adopted plan pods");
    let rb2 = cb2.run().unwrap();
    assert!(rb2.completed);

    // the continued curve is bit-identical to the uninterrupted W=4 run
    let merged: Vec<(usize, u32)> = rb1
        .losses
        .iter()
        .chain(rb2.losses.iter())
        .map(|&(s, l)| (s, l.to_bits()))
        .collect();
    let reference: Vec<(usize, u32)> =
        ra.losses.iter().map(|&(s, l)| (s, l.to_bits())).collect();
    assert_eq!(merged, reference, "resharded campaign must equal the uninterrupted one");
    for (ta, tb) in ca.trainer.params.tensors.iter().zip(&cb2.trainer.params.tensors) {
        assert!(bits_eq(ta.f32s(), tb.f32s()), "final params must be bit-identical");
    }
    let (am, av) = ca.trainer.moments_flat();
    let (bm, bv) = cb2.trainer.moments_flat();
    assert!(bits_eq(&am, &bm), "first moment across topologies");
    assert!(bits_eq(&av, &bv), "second moment across topologies");
    assert!(
        bits_eq(ca.trainer.scale_mgr.scales(), cb2.trainer.scale_mgr.scales()),
        "delayed-scaling state across topologies"
    );

    // topology history on the record: reshard event with old→new
    let ev = journal::read(base.join("b").join("journal.jsonl")).unwrap();
    assert_eq!(journal::count(&ev, "reshard"), 1);
    let rs = journal::last(&ev, "reshard").unwrap();
    assert_eq!(rs.usize_of("from_workers").unwrap(), 4);
    assert_eq!(rs.usize_of("to_workers").unwrap(), 3);
    assert!(rs.str_of("from_topology").unwrap().contains("w4"));
    assert!(rs.str_of("to_topology").unwrap().contains("w3"));
    #[cfg(target_os = "linux")]
    assert_eq!(journal::count(&ev, "lock_reclaimed"), 1, "stale-lock reclaim journaled");
    let res = journal::last(&ev, "resume").unwrap();
    assert_eq!(
        res.get("resharded"),
        Some(&fp8_trainer::util::json::Json::Bool(true)),
        "the resume event records that it resharded"
    );
    std::fs::remove_dir_all(&base).ok();
}
