//! Tile-wise-scaled FP8 GEMM tests.
//!
//! Two tiers, matching the repo's integration-test convention:
//!
//! * **artifact-free** — the differential bit-exactness matrix: the
//!   fast tiled kernels (`matmul_f32`, `matmul_fp8`) against their
//!   scalar serial references (`matmul_f32_naive`, `matmul_fp8_ref`)
//!   across shapes {ragged, tile-aligned, 1×N, N×1} × formats
//!   {E4M3, E5M2} × every transpose variant, plus the fwd/bwd linear
//!   pair and NaN transparency. Equality is `to_bits`, no tolerance —
//!   the kernels pin one summation order (ascending k into a single
//!   f32 accumulator per output element) and must agree exactly.
//! * **artifact-gated** — the Fig. 2 divergence reproduction as a
//!   regression test: in the *same* run configuration (seeded outlier
//!   channel, elevated lr/wd, non-finite passthrough), the `fp8_gemm`
//!   recipe on the plain-SwiGLU graph destabilizes while
//!   `fp8_gemm_smooth` (Smooth-SwiGLU) tracks bf16. Skips with a note
//!   when `artifacts/` is absent (run `make artifacts` first).

use std::sync::{Arc, OnceLock};

use fp8_trainer::config::TrainConfig;
use fp8_trainer::coordinator::Trainer;
use fp8_trainer::fp8::{E4M3, E5M2};
use fp8_trainer::gemm::{
    fp8_linear_bwd, fp8_linear_fwd, matmul_f32, matmul_f32_naive, matmul_fp8, matmul_fp8_ref,
    GemmConfig, TileQuant,
};
use fp8_trainer::runtime::Runtime;

// ---------------------------------------------------------------- helpers

fn data(n: usize, phase: f32, span: f32) -> Vec<f32> {
    (0..n).map(|i| ((i as f32) * 0.731 + phase).sin() * span).collect()
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: element {i} differs ({x} vs {y})"
        );
    }
}

/// The differential matrix: op-shapes (m, k, n) covering ragged,
/// tile-aligned (at tile 4), single-row, single-column and
/// tall-skinny; every (trans_a, trans_b) combination.
const SHAPES: [(usize, usize, usize); 5] =
    [(9, 7, 11), (8, 8, 8), (1, 5, 9), (9, 5, 1), (3, 17, 2)];
const TRANSPOSES: [(bool, bool); 4] =
    [(false, false), (true, false), (false, true), (true, true)];

/// Storage dims of an operand whose op-shape is `r × c`.
fn storage(r: usize, c: usize, trans: bool) -> (usize, usize) {
    if trans {
        (c, r)
    } else {
        (r, c)
    }
}

// ------------------------------------------------- artifact-free tier

#[test]
fn f32_tiled_matches_naive_across_shapes_and_transposes() {
    for &(m, k, n) in &SHAPES {
        for &(ta, tb) in &TRANSPOSES {
            let (ar, ac) = storage(m, k, ta);
            let (br, bc) = storage(k, n, tb);
            let a = data(ar * ac, 0.2, 2.0);
            let b = data(br * bc, 1.4, 2.0);
            let fast = matmul_f32(&a, ar, ac, ta, &b, br, bc, tb).unwrap();
            let slow = matmul_f32_naive(&a, ar, ac, ta, &b, br, bc, tb).unwrap();
            assert_eq!((fast.rows, fast.cols), (m, n));
            assert_bits_eq(&fast.data, &slow.data, &format!("f32 {m}x{k}x{n} t{ta}/{tb}"));
        }
    }
}

#[test]
fn fp8_tiled_matches_scalar_reference_across_full_matrix() {
    // tile 4 exercises ragged interior tiles at these shapes; tile 128
    // is the single-tile degenerate case (every shape fits one tile)
    for tile in [4usize, 128] {
        for fmt in [E4M3, E5M2] {
            for &(m, k, n) in &SHAPES {
                for &(ta, tb) in &TRANSPOSES {
                    let (ar, ac) = storage(m, k, ta);
                    let (br, bc) = storage(k, n, tb);
                    let a = TileQuant::quantize(fmt, tile, &data(ar * ac, 0.7, 3.0), ar, ac);
                    let b = TileQuant::quantize(fmt, tile, &data(br * bc, 2.1, 3.0), br, bc);
                    let fast = matmul_fp8(&a, ta, &b, tb).unwrap();
                    let slow = matmul_fp8_ref(&a, ta, &b, tb).unwrap();
                    assert_eq!((fast.rows, fast.cols), (m, n));
                    assert_bits_eq(
                        &fast.data,
                        &slow.data,
                        &format!("fp8 {fmt:?} t{tile} {m}x{k}x{n} trans {ta}/{tb}"),
                    );
                }
            }
        }
    }
}

#[test]
fn mixed_operand_formats_match_reference() {
    // E4M3 weights × E5M2 grads — the per-operand format split the
    // backward pass uses (dX = dY·Wᵀ pairs an E5M2 operand with E4M3)
    let (m, k, n) = (6, 10, 5);
    let dy = TileQuant::quantize(E5M2, 4, &data(m * k, 0.3, 0.5), m, k);
    let w = TileQuant::quantize(E4M3, 4, &data(n * k, 1.1, 0.2), n, k);
    let fast = matmul_fp8(&dy, false, &w, true).unwrap();
    let slow = matmul_fp8_ref(&dy, false, &w, true).unwrap();
    assert_bits_eq(&fast.data, &slow.data, "mixed-format dY·Wᵀ");
}

#[test]
fn linear_fwd_bwd_match_scalar_reference() {
    let cfg = GemmConfig { tile: 4, ..Default::default() };
    let (m, k, n) = (7, 9, 6);
    let x = data(m * k, 0.1, 1.0);
    let w = data(k * n, 0.9, 0.2);
    let (y, xq, wq) = fp8_linear_fwd(&cfg, &x, m, k, &w, n).unwrap();
    assert_eq!(xq.fmt, cfg.x_fmt);
    assert_eq!(wq.fmt, cfg.w_fmt);
    let y_ref = matmul_fp8_ref(&xq, false, &wq, false).unwrap();
    assert_bits_eq(&y.data, &y_ref.data, "forward Y = X·W");

    let dy = data(m * n, 1.7, 0.05);
    let (dx, dw) = fp8_linear_bwd(&cfg, &dy, &xq, &wq).unwrap();
    let dyq = TileQuant::quantize(cfg.g_fmt, cfg.tile, &dy, m, n);
    assert_eq!(dyq.fmt, E5M2, "grads default to E5M2");
    let dx_ref = matmul_fp8_ref(&dyq, false, &wq, true).unwrap();
    let dw_ref = matmul_fp8_ref(&xq, true, &dyq, false).unwrap();
    assert_eq!((dx.rows, dx.cols), (m, k));
    assert_eq!((dw.rows, dw.cols), (k, n));
    assert_bits_eq(&dx.data, &dx_ref.data, "backward dX = dY·Wᵀ");
    assert_bits_eq(&dw.data, &dw_ref.data, "backward dW = Xᵀ·dY");
}

#[test]
fn nan_poisons_its_output_row_and_nothing_else() {
    let cfg = GemmConfig { tile: 4, ..Default::default() };
    let (m, k, n) = (6, 8, 5);
    let mut x = data(m * k, 0.4, 1.0);
    let w = data(k * n, 1.9, 0.3);
    let (clean, _, wq) = fp8_linear_fwd(&cfg, &x, m, k, &w, n).unwrap();
    x[2 * k + 3] = f32::NAN;
    let xq = TileQuant::quantize(cfg.x_fmt, cfg.tile, &x, m, k);
    let y = matmul_fp8(&xq, false, &wq, false).unwrap();
    for j in 0..n {
        assert!(y.at(2, j).is_nan(), "row 2 must be fully poisoned (col {j})");
    }
    for i in (0..m).filter(|&i| i != 2) {
        for j in 0..n {
            assert_eq!(
                y.at(i, j).to_bits(),
                clean.at(i, j).to_bits(),
                "row {i} must be untouched by the NaN in row 2"
            );
        }
    }
    // ... because the poisoned tile's *scale* ignored the NaN: its
    // neighbors inside the same tile stayed on the clean grid
    let clean_q = TileQuant::quantize(cfg.x_fmt, cfg.tile, &data(m * k, 0.4, 1.0), m, k);
    assert_bits_eq(&xq.scales, &clean_q.scales, "tile scales under NaN");
}

#[test]
fn shape_mismatch_is_an_error_not_a_panic() {
    let a = data(6, 0.0, 1.0);
    let b = data(6, 0.0, 1.0);
    assert!(matmul_f32(&a, 2, 3, false, &b, 2, 3, false).is_err(), "3 != 2 inner dims");
    let aq = TileQuant::quantize(E4M3, 4, &a, 2, 3);
    let bq = TileQuant::quantize(E4M3, 4, &b, 2, 3);
    assert!(matmul_fp8(&aq, false, &bq, false).is_err());
    assert!(matmul_fp8(&aq, false, &bq, true).is_ok(), "A[2,3] · Bᵀ[3,2] is fine");
}

// ------------------------------------------------ artifact-gated tier

/// One shared PJRT client for the whole test binary (the TFRT CPU
/// client does not tolerate repeated create/destroy in one process),
/// or None on a bare checkout without `artifacts/`.
fn runtime() -> Option<Arc<Runtime>> {
    static RT: OnceLock<Option<Arc<Runtime>>> = OnceLock::new();
    RT.get_or_init(|| Runtime::new("artifacts").ok().map(Arc::new)).clone()
}

macro_rules! need_artifacts {
    () => {
        match runtime() {
            Some(rt) => rt,
            None => {
                eprintln!("skipping: artifacts/ not found (run `make artifacts` first)");
                return;
            }
        }
    };
}

/// The Fig. 2 run configuration (mirrors `benches/fig2_divergence.rs`):
/// partially-aligned outlier channel seeded into w1/w2 of layer 0,
/// elevated lr/wd to compress the 200B-token alignment, and non-finite
/// updates passed through so the paper's hard divergence is visible.
fn fig2_cfg(recipe: &str, steps: usize) -> TrainConfig {
    TrainConfig {
        size: "s1m".into(),
        recipe: recipe.into(),
        steps,
        warmup_steps: 20,
        lr: 8e-4,
        weight_decay: 0.3,
        seed_outlier_channel: true,
        seed_outlier_gain: 3.0,
        skip_nonfinite_updates: false,
        out_dir: "runs/gemm_fig2_test".into(),
        ..Default::default()
    }
}

/// Run one recipe to completion (or until well past divergence) and
/// report (final loss, diverged_at).
fn fig2_run(rt: &Arc<Runtime>, recipe: &str, steps: usize) -> (f32, Option<usize>) {
    let mut t = Trainer::new(rt.clone(), fig2_cfg(recipe, steps))
        .unwrap_or_else(|e| panic!("trainer for {recipe}: {e}"));
    let mut last = f32::NAN;
    let mut after_div = 0;
    for _ in 0..steps {
        let o = t.step().unwrap_or_else(|e| panic!("step under {recipe}: {e}"));
        if o.loss.is_finite() {
            last = o.loss;
        }
        if t.detector.has_diverged() {
            after_div += 1;
            if after_div > 10 {
                break;
            }
        }
    }
    (last, t.detector.diverged_at)
}

/// The paper's Fig. 2 contrast as a regression gate: same seeds, same
/// data, same lr/wd, same outlier channel — the only variable is the
/// compute path. `fp8_gemm` (tile-wise FP8 GEMMs over the plain-SwiGLU
/// graph) must destabilize; `fp8_gemm_smooth` (identical, plus
/// Smooth-SwiGLU's per-channel scaling) must track the bf16 reference.
#[test]
fn fig2_gemm_diverges_and_smooth_gemm_tracks_bf16() {
    let rt = need_artifacts!();
    let steps = 400;

    let (bf16_loss, bf16_div) = fig2_run(&rt, "bf16", steps);
    assert!(bf16_div.is_none(), "BF16 must stay healthy (paper Fig. 2a)");

    let (_, gemm_div) = fig2_run(&rt, "fp8_gemm", steps);
    assert!(
        gemm_div.is_some(),
        "fp8_gemm on the plain-SwiGLU graph must destabilize under the outlier \
         channel (paper Fig. 2a) — the detector never fired in {steps} steps"
    );

    let (smooth_loss, smooth_div) = fig2_run(&rt, "fp8_gemm_smooth", steps);
    assert!(
        smooth_div.is_none(),
        "fp8_gemm_smooth must not diverge (diverged at {smooth_div:?})"
    );
    let rel = (smooth_loss - bf16_loss).abs() / bf16_loss.abs().max(1e-6);
    assert!(
        rel < 0.25,
        "fp8_gemm_smooth final loss {smooth_loss} must track bf16 {bf16_loss} \
         (relative gap {rel:.3} >= 0.25)"
    );
}

/// Resume under a changed GEMM setup must refuse with the `gemm` term
/// named — the PR-7 actionable-diagnostics contract extended to the
/// compute path. Artifact-gated because capture needs a live trainer.
#[test]
fn resume_under_changed_gemm_tile_refuses_with_term_diff() {
    use fp8_trainer::campaign::snapshot::TrainState;
    let rt = need_artifacts!();
    let mut cfg = fig2_cfg("fp8_gemm_smooth", 6);
    cfg.seed_outlier_channel = false;
    let mut t = Trainer::new(rt.clone(), cfg.clone()).unwrap();
    for _ in 0..3 {
        t.step().unwrap();
    }
    let state = TrainState::capture(&t, 0);

    let mut tile = cfg.clone();
    tile.gemm_tile = 64;
    let mut other = Trainer::new(rt.clone(), tile).unwrap();
    let err = state
        .apply_to(&mut other)
        .expect_err("changed gemm tile must refuse to resume")
        .to_string();
    assert!(err.contains("gemm"), "refusal must name the gemm term: {err}");

    // unchanged config still resumes cleanly
    let mut same = Trainer::new(rt, cfg).unwrap();
    state.apply_to(&mut same).unwrap();
    assert_eq!(same.step, 3);
}
