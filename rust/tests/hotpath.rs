//! Hot-path equivalence suite: the bulk table-driven codec must be a
//! bit-exact drop-in for the scalar reference (`Fp8Format::encode` /
//! `decode`), and the parallel collective/norm paths must be
//! bit-deterministic. No artifacts needed — pure Rust.

use fp8_trainer::coordinator::allreduce::{
    allreduce_mean, global_norm, reduce_mean_into_rank0, NORM_CHUNK,
};
use fp8_trainer::fp8::{self, bulk, E4M3, E5M2};
use fp8_trainer::util::prng::Rng;

fn same_f32(a: f32, b: f32) -> bool {
    a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
}

// ---------------------------------------------------------------- codec

#[test]
fn bulk_decode_matches_scalar_on_all_256_codes() {
    for fmt in [E4M3, E5M2] {
        let codes: Vec<u8> = (0..=255u8).collect();
        let mut out = Vec::new();
        bulk::decode_slice_into(fmt, &codes, &mut out);
        for (code, &v) in out.iter().enumerate() {
            let reference = fmt.decode(code as u8);
            assert!(
                same_f32(v, reference),
                "{fmt:?} code {code:#x}: bulk {v} vs scalar {reference}"
            );
        }
    }
}

#[test]
fn bulk_encode_roundtrips_all_256_codes() {
    // decode every code with the scalar codec, bulk-encode the values,
    // and require the scalar encoder's byte back (identity on the code
    // wheel except NaN patterns and E5M2 inf canonicalization — the
    // scalar codec is the oracle for those too)
    for fmt in [E4M3, E5M2] {
        let values: Vec<f32> = (0..=255u8).map(|c| fmt.decode(c)).collect();
        let mut bulk_bytes = Vec::new();
        bulk::encode_slice_into(fmt, &values, &mut bulk_bytes);
        for (code, (&v, &back)) in values.iter().zip(&bulk_bytes).enumerate() {
            assert_eq!(
                back,
                fmt.encode(v),
                "{fmt:?} code {code:#x} (value {v}): bulk disagrees with scalar"
            );
        }
    }
}

/// 1M deterministic PRNG f32s: raw bit patterns (hits NaN payloads,
/// infs, subnormals, both zeros) interleaved with scaled normals and a
/// block of handpicked boundary values.
fn sweep_inputs() -> Vec<f32> {
    let mut rng = Rng::new(0x5eed_f8);
    let specials = [
        0.0f32,
        -0.0,
        f32::NAN,
        -f32::NAN,
        f32::INFINITY,
        f32::NEG_INFINITY,
        f32::MIN_POSITIVE,
        f32::MIN_POSITIVE / 8.0,
        2f32.powi(-6),
        2f32.powi(-9),
        2f32.powi(-10),
        2f32.powi(-14),
        2f32.powi(-16),
        2f32.powi(-17),
        447.9,
        448.0,
        463.99,
        464.0,
        464.01,
        495.99,
        496.0,
        512.0,
        57344.0,
        61439.9,
        61440.0,
        61440.1,
        65535.9,
        65536.0,
        1e9,
        3.4e38,
    ];
    let mut xs = Vec::with_capacity(1_000_000);
    for i in 0..1_000_000usize {
        let x = match i % 4 {
            // raw bit pattern: uniform over the entire f32 space
            0 => f32::from_bits(rng.next_u64() as u32),
            // normal-ish magnitudes around the fp8 ranges
            1 => (rng.normal() as f32) * 30.0,
            // log-uniform magnitudes: exercises every binade incl.
            // fp8 subnormal and overflow territory
            2 => {
                let e = (rng.uniform() * 90.0 - 45.0) as f32;
                let sign = if rng.below(2) == 0 { 1.0 } else { -1.0 };
                sign * 2f32.powf(e)
            }
            _ => specials[i % specials.len()],
        };
        xs.push(x);
    }
    xs
}

#[test]
fn bulk_encode_matches_scalar_on_1m_prng_sweep() {
    let xs = sweep_inputs();
    for fmt in [E4M3, E5M2] {
        let mut bytes = Vec::new();
        bulk::encode_slice_into(fmt, &xs, &mut bytes);
        assert_eq!(bytes.len(), xs.len());
        for (i, (&x, &b)) in xs.iter().zip(&bytes).enumerate() {
            let reference = fmt.encode(x);
            assert_eq!(
                b, reference,
                "{fmt:?} i={i} x={x} ({:#010x}): bulk {b:#04x} vs scalar {reference:#04x}",
                x.to_bits()
            );
        }
    }
}

#[test]
fn bulk_decode_matches_scalar_on_1m_sweep() {
    // decode the full byte distribution, not just 256 singletons:
    // exercises the parallel span split at every offset alignment
    let mut rng = Rng::new(0xdec0de);
    let bytes: Vec<u8> = (0..1_000_000).map(|_| rng.next_u64() as u8).collect();
    for fmt in [E4M3, E5M2] {
        let mut out = Vec::new();
        bulk::decode_slice_into(fmt, &bytes, &mut out);
        for (i, (&b, &v)) in bytes.iter().zip(&out).enumerate() {
            assert!(same_f32(v, fmt.decode(b)), "{fmt:?} i={i} byte {b:#04x}");
        }
    }
}

#[test]
fn pack_scaled_nan_regression() {
    // NaN is invisible to the amax fold; it must still (a) come back
    // as NaN, (b) leave the scale exactly what the finite elements
    // alone would produce, (c) leave every finite byte unchanged.
    let mut rng = Rng::new(7);
    let mut xs: Vec<f32> = (0..10_000).map(|_| (rng.normal() as f32) * 0.1).collect();
    for idx in [0usize, 4999, 9999] {
        xs[idx] = if idx % 2 == 0 { f32::NAN } else { -f32::NAN };
    }
    // the NaN-free reference: NaNs contribute nothing to the amax, so
    // zeroing them must give exactly the same scale
    let clean: Vec<f32> = xs.iter().map(|&x| if x.is_nan() { 0.0 } else { x }).collect();
    for fmt in [E4M3, E5M2] {
        let (bytes, scale) = fp8::pack_scaled(fmt, &xs);
        let (clean_bytes, clean_scale) = fp8::pack_scaled(fmt, &clean);
        assert_eq!(scale, clean_scale, "{fmt:?}: NaN moved the scale");
        for idx in [0usize, 4999, 9999] {
            assert!(fmt.decode(bytes[idx]).is_nan(), "{fmt:?}: NaN lost at {idx}");
        }
        for (i, (&b, &cb)) in bytes.iter().zip(&clean_bytes).enumerate() {
            if ![0usize, 4999, 9999].contains(&i) {
                assert_eq!(b, cb, "{fmt:?}: finite byte {i} perturbed by NaN neighbor");
            }
        }
        let mut back = Vec::new();
        fp8::unpack_scaled(fmt, &bytes, scale, &mut back);
        assert!(back[0].is_nan() && back[4999].is_nan() && back[9999].is_nan());
    }
}

#[test]
fn pack_unpack_into_reuse_buffers_across_sizes() {
    // caller-owned buffers: shrinking and growing inputs must be exact
    let mut bytes = Vec::new();
    let mut back = Vec::new();
    for n in [10usize, 100_000, 17, 65_536, 0, 3] {
        let xs: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.031).sin()).collect();
        let scale = bulk::pack_scaled_into(E4M3, &xs, &mut bytes);
        assert_eq!(bytes.len(), n);
        bulk::unpack_scaled_into(E4M3, &bytes, scale, &mut back);
        assert_eq!(back.len(), n);
        for (&x, &y) in xs.iter().zip(&back) {
            assert!((x - y).abs() <= x.abs() * 0.07 + 1e-3, "n={n}: {x} vs {y}");
        }
    }
}

// ----------------------------------------------------------- collective

#[test]
fn reduce_mean_into_rank0_bit_matches_allreduce() {
    // large enough to cross the parallel add threshold
    let n = 200_000;
    let w = 5;
    let mk = || -> Vec<Vec<f32>> {
        let mut rng = Rng::new(42);
        (0..w)
            .map(|_| (0..n).map(|_| (rng.normal() as f32) * 0.01).collect())
            .collect()
    };
    let mut a = mk();
    let mut b = mk();
    allreduce_mean(&mut a);
    reduce_mean_into_rank0(&mut b);
    for (i, (x, y)) in a[0].iter().zip(&b[0]).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "rank0 diverges at {i}");
    }
}

#[test]
fn global_norm_is_bit_deterministic_and_chunk_defined() {
    // the chunked-parallel norm must equal the explicit fixed-chunk
    // fold bit-for-bit, and repeated runs must agree exactly
    let n = NORM_CHUNK * 5 + 321;
    let mut rng = Rng::new(11);
    let flat: Vec<f32> = (0..n).map(|_| (rng.normal() as f32) * 0.003).collect();
    let expect = flat
        .chunks(NORM_CHUNK)
        .map(|c| c.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>())
        .sum::<f64>()
        .sqrt() as f32;
    let g1 = global_norm(&flat);
    let g2 = global_norm(&flat);
    assert_eq!(g1.to_bits(), expect.to_bits(), "parallel != chunk definition");
    assert_eq!(g1.to_bits(), g2.to_bits(), "norm not reproducible");
}
