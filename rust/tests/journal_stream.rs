//! Streaming-journal + fleet-aggregation tests.
//!
//! The contract under test (docs/JOURNAL.md):
//! * `journal::stream` parses event-at-a-time with O(1) memory and is
//!   **equivalent** to the whole-file reader on any input — same
//!   events, same skip count — including torn tails and garbage;
//! * a mid-record crash (torn, newline-less tail) is skipped AND
//!   counted, and `Journal::open`'s repair journals `tail_repaired`;
//! * a line beyond `MAX_LINE_BYTES` is a typed `OversizedLine`
//!   refusal, not an unbounded buffer;
//! * `tail(n)` (end-seeked) returns exactly the last n events even
//!   with damage interleaved;
//! * the fleet aggregator folds healthy + torn + locked campaign dirs
//!   correctly in one streaming pass each, degrades per-campaign, and
//!   its Prometheus/JSON renders are well-formed.
//!
//! All artifact-free — these always run.

use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};

use fp8_trainer::campaign::fleet::{self, Phase};
use fp8_trainer::campaign::journal::{self, stream};
use fp8_trainer::campaign::Journal;
use fp8_trainer::util::json::Json;

fn tmp_path(tag: &str) -> PathBuf {
    static K: AtomicUsize = AtomicUsize::new(0);
    let k = K.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("fp8_jstream_{}_{}_{}", tag, std::process::id(), k))
}

/// The historical whole-file acceptance rule, written naively: slurp,
/// split lines, parse what parses, count what doesn't. The streaming
/// parser must match this on every input.
fn naive_read(path: &Path) -> (Vec<Json>, usize) {
    let text = std::fs::read(path).unwrap();
    let text = String::from_utf8_lossy(&text);
    let mut events = Vec::new();
    let mut skipped = 0;
    for line in text.lines() {
        let t = line.trim();
        if t.is_empty() {
            continue;
        }
        match Json::parse(t) {
            Ok(j) => events.push(j),
            Err(_) => skipped += 1,
        }
    }
    (events, skipped)
}

fn append_raw(path: &Path, bytes: &[u8]) {
    let mut f = std::fs::OpenOptions::new().append(true).open(path).unwrap();
    f.write_all(bytes).unwrap();
}

/// A journal with real events, blank lines, and three flavors of
/// damage (garbage text, invalid UTF-8, a torn JSON fragment mid-file
/// followed by intact lines — the "crashed, repaired, kept going"
/// history).
fn battle_scarred_journal(tag: &str) -> (PathBuf, PathBuf) {
    let dir = tmp_path(tag);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("journal.jsonl");
    {
        let mut j = Journal::open(&path).unwrap();
        j.record("campaign_start", 0, vec![]).unwrap();
        for i in 1..=20 {
            j.record("snapshot", i * 10, vec![("loss", Json::Num(3.0 - i as f64 * 0.05))])
                .unwrap();
        }
        j.flush().unwrap();
    }
    append_raw(&path, b"not json at all\n");
    append_raw(&path, b"\n\n");
    append_raw(&path, &[0xff, 0xfe, b'x', b'\n']); // invalid UTF-8
    append_raw(&path, b"{\"event\":\"snapsh"); // torn tail, no newline
    {
        // reopen repairs the tear (journaling it) and appends intact
        let mut j = Journal::open(&path).unwrap();
        j.record("resume", 200, vec![]).unwrap();
        j.record("complete", 210, vec![("final_loss", Json::Num(2.0))]).unwrap();
        j.flush().unwrap();
    }
    (dir, path)
}

#[test]
fn stream_is_equivalent_to_the_whole_file_reader() {
    let (dir, path) = battle_scarred_journal("equiv");
    let (want_events, want_skipped) = naive_read(&path);
    assert!(want_skipped >= 3, "fixture must contain damage");
    assert!(want_events.len() >= 23);

    // iterator face
    let mut s = stream::JournalStream::from_path(&path).unwrap();
    let mut got = Vec::new();
    while let Some(e) = s.next_event().unwrap() {
        got.push(e);
    }
    assert_eq!(got, want_events, "streamed events == whole-file events");
    assert_eq!(s.skipped(), want_skipped, "streamed skip count == naive skip count");

    // collected faces agree too
    let out = journal::read_counted(&path).unwrap();
    assert_eq!(out.events, want_events);
    assert_eq!(out.skipped, want_skipped);
    assert_eq!(journal::read(&path).unwrap(), want_events);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mid_record_crash_is_skipped_counted_and_repaired() {
    let dir = tmp_path("crash");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("journal.jsonl");
    {
        let mut j = Journal::open(&path).unwrap();
        j.record("campaign_start", 0, vec![]).unwrap();
        j.record("snapshot", 50, vec![("loss", Json::Num(2.5))]).unwrap();
        j.flush().unwrap();
    }
    // crash mid-record: half a JSON object, no terminator
    append_raw(&path, b"{\"event\":\"snapshot\",\"step\":60,\"lo");
    let out = journal::read_counted(&path).unwrap();
    assert_eq!(out.events.len(), 2, "intact prefix still reads");
    assert_eq!(out.skipped, 1, "the torn record is counted, not silently dropped");

    // writer reopen = repair: journaled, and appends stay intact
    {
        let mut j = Journal::open(&path).unwrap();
        j.record("resume", 50, vec![]).unwrap();
        j.flush().unwrap();
    }
    let out = journal::read_counted(&path).unwrap();
    assert_eq!(out.skipped, 1);
    let kinds: Vec<_> =
        out.events.iter().map(|e| e.str_or("event", "?")).collect();
    assert!(kinds.contains(&"tail_repaired".to_string()), "repair is journaled: {kinds:?}");
    assert!(kinds.contains(&"resume".to_string()));

    // a valid-JSON final line missing only its newline is an event,
    // not damage
    append_raw(&path, b"{\"event\":\"pause\",\"step\":70,\"unix_ms\":1}");
    let out = journal::read_counted(&path).unwrap();
    assert_eq!(out.events.last().unwrap().str_or("event", "?"), "pause");
    assert_eq!(out.skipped, 1, "unterminated-but-valid tail is not a skip");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn oversized_line_is_refused_with_a_typed_error() {
    let dir = tmp_path("oversize");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("journal.jsonl");
    let big = format!("{{\"event\":\"snapshot\",\"pad\":\"{}\"}}\n", "x".repeat(256));
    std::fs::write(&path, format!("{{\"event\":\"campaign_start\",\"step\":0}}\n{big}")).unwrap();

    let f = std::fs::File::open(&path).unwrap();
    let mut s =
        stream::JournalStream::with_max_line(std::io::BufReader::new(f), 64);
    assert!(s.next_event().unwrap().is_some(), "first line is under the limit");
    let err = s.next_event().expect_err("oversized line must refuse");
    let typed = err
        .downcast_ref::<stream::OversizedLine>()
        .expect("error downcasts to OversizedLine");
    assert_eq!(typed.limit, 64);
    assert!(typed.len_at_least > 64);
    assert_eq!(typed.line, 2, "1-indexed offending line");

    // the default bound admits any line the writer emits
    let out = journal::read_counted(&path).unwrap();
    assert_eq!(out.events.len(), 2);
    assert!(stream::MAX_LINE_BYTES >= 1 << 20);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn tail_seeks_exactly_the_last_n_even_through_damage() {
    let (dir, path) = battle_scarred_journal("tail");
    let all = journal::read(&path).unwrap();
    for n in [0, 1, 2, 5, all.len(), all.len() + 50] {
        let t = journal::tail(&path, n).unwrap();
        let want = &all[all.len().saturating_sub(n)..];
        assert_eq!(t.events, want, "tail({n})");
    }
    // missing journal is an error, empty journal is empty
    assert!(journal::tail(dir.join("nope.jsonl"), 3).is_err());
    let empty = dir.join("empty.jsonl");
    std::fs::write(&empty, b"").unwrap();
    assert!(journal::tail(&empty, 3).unwrap().events.is_empty());
    std::fs::remove_dir_all(&dir).ok();
}

/// Build a fleet root: healthy complete campaign, torn-tail campaign,
/// locked (live-pid) campaign — nested one level to exercise
/// discovery — plus decoys that must not be picked up.
fn build_fleet_root() -> PathBuf {
    let root = tmp_path("fleetroot");

    // healthy: completed with losses, a divergence drill, a recovery
    let a = root.join("exp-a").join("campaign");
    std::fs::create_dir_all(&a).unwrap();
    {
        let mut j = Journal::open(a.join("journal.jsonl")).unwrap();
        j.record("campaign_start", 0, vec![]).unwrap();
        j.record("snapshot", 10, vec![("loss", Json::Num(2.9))]).unwrap();
        j.record(
            "divergence",
            15,
            vec![("loss", Json::Num(9.0)), ("injected", Json::Bool(true))],
        )
        .unwrap();
        j.record("recovery", 10, vec![("attempt", Json::Num(1.0))]).unwrap();
        j.record("snapshot", 20, vec![("loss", Json::Num(2.7))]).unwrap();
        j.record(
            "complete",
            30,
            vec![("final_loss", Json::Num(2.5)), ("recoveries", Json::Num(1.0))],
        )
        .unwrap();
        j.flush().unwrap();
    }

    // torn: crashed mid-record, never resumed
    let b = root.join("exp-b").join("campaign");
    std::fs::create_dir_all(&b).unwrap();
    {
        let mut j = Journal::open(b.join("journal.jsonl")).unwrap();
        j.record("campaign_start", 0, vec![]).unwrap();
        j.record("snapshot", 5, vec![("loss", Json::Num(3.1))]).unwrap();
        j.flush().unwrap();
    }
    append_raw(&b.join("journal.jsonl"), b"{\"event\":\"snapsh");

    // locked by a live pid (our own): phase must be running on Linux
    let c = root.join("exp-c");
    std::fs::create_dir_all(&c).unwrap();
    {
        let mut j = Journal::open(c.join("journal.jsonl")).unwrap();
        j.record("campaign_start", 0, vec![]).unwrap();
        j.record("snapshot", 100, vec![("loss", Json::Num(2.0))]).unwrap();
        j.flush().unwrap();
    }
    std::fs::write(c.join("LOCK"), format!("{}", std::process::id())).unwrap();

    // decoys: a snapshots/ subtree and a dot-dir with journals that
    // must NOT be discovered, and an unrelated empty dir
    let d = root.join("exp-a").join("campaign").join("snapshots");
    std::fs::create_dir_all(&d).unwrap();
    let dot = root.join(".trash").join("old");
    std::fs::create_dir_all(&dot).unwrap();
    std::fs::write(dot.join("journal.jsonl"), b"{}\n").unwrap();
    std::fs::create_dir_all(root.join("not-a-campaign")).unwrap();

    root
}

#[test]
fn fleet_aggregates_healthy_torn_and_locked_campaigns_in_one_pass() {
    let root = build_fleet_root();
    let view = fleet::scan_root(&root).unwrap();
    assert_eq!(view.campaigns.len(), 3, "exactly the three campaign dirs");
    let names: Vec<_> = view.campaigns.iter().map(|c| c.name.as_str()).collect();
    assert_eq!(
        names,
        vec!["exp-a/campaign", "exp-b/campaign", "exp-c"],
        "sorted, root-relative, decoys excluded"
    );

    let a = &view.campaigns[0];
    assert_eq!(a.phase(), Phase::Complete);
    assert_eq!(a.events, 6);
    assert_eq!(a.skipped_lines, 0);
    assert_eq!(a.last_loss, 2.5, "complete.final_loss wins");
    assert_eq!(a.max_step, 30);
    assert_eq!(a.count("divergence"), 1);
    assert_eq!(a.recent_divergences.len(), 1);
    assert!(a.recent_divergences[0].injected);
    assert_eq!(
        a.recent_losses.iter().map(|&(s, _)| s).collect::<Vec<_>>(),
        vec![10, 20, 30],
        "loss trail from snapshot+complete events"
    );

    let b = &view.campaigns[1];
    assert_eq!(b.skipped_lines, 1, "the torn tail is surfaced, not hidden");
    assert_eq!(b.events, 2);
    assert_eq!(b.phase(), Phase::Idle, "no lock, no terminal event");

    let c = &view.campaigns[2];
    if cfg!(target_os = "linux") {
        assert_eq!(c.phase(), Phase::Running, "live-pid lock");
    } else {
        assert_eq!(c.phase(), Phase::Locked);
    }

    let t = view.totals();
    assert_eq!(t.campaigns, 3);
    assert_eq!(t.complete, 1);
    assert_eq!(t.divergences, 1);
    assert_eq!(t.recoveries, 1);
    assert_eq!(t.skipped_lines, 1);

    // renders: table carries the skip warning, every campaign appears
    let table = view.render_status();
    for n in &names {
        assert!(table.contains(n), "status table lists {n}:\n{table}");
    }
    assert!(table.contains("WARNING"), "fleet-wide skip warning:\n{table}");
    assert!(view.render_losses().contains("2.5000"));
    assert!(view.render_divergences().contains("injected"));
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn fleet_prometheus_and_json_exports_are_well_formed() {
    let root = build_fleet_root();
    let view = fleet::scan_root(&root).unwrap();

    let prom = view.render_prometheus();
    assert!(prom.contains("# TYPE fp8_fleet_campaigns gauge"));
    assert!(prom.contains("fp8_fleet_campaigns 3"));
    assert!(prom.contains("fp8_fleet_journal_skipped_lines 1"));
    assert!(prom.contains(r#"fp8_campaign_last_loss{campaign="exp-a/campaign"} 2.5"#));
    assert!(prom.contains(r#"phase="complete""#));
    // every sample line is `series value` with a float-parseable value
    for line in prom.lines().filter(|l| !l.starts_with('#')) {
        let (series, val) = line.rsplit_once(' ').expect("sample shape");
        assert!(!series.is_empty());
        assert!(val.parse::<f64>().is_ok(), "unparseable sample: {line}");
    }

    // the JSON dump round-trips through our own parser
    let dump = view.to_json().to_string();
    let parsed = Json::parse(&dump).expect("fleet JSON parses");
    let campaigns = parsed.get("campaigns").and_then(|c| c.as_arr()).unwrap();
    assert_eq!(campaigns.len(), 3);
    let totals = parsed.get("totals").unwrap();
    assert_eq!(totals.usize_of("skipped_lines").unwrap(), 1);
    let b = &campaigns[1];
    assert_eq!(b.str_of("name").unwrap(), "exp-b/campaign");
    assert_eq!(b.usize_of("skipped_lines").unwrap(), 1);
    // a campaign with no loss yet exports null, not NaN (JSON has none)
    assert!(!dump.to_lowercase().contains("nan"), "no NaN leaks into JSON");
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn fleet_root_errors_and_single_damaged_campaign_degrade_gracefully() {
    // nonexistent root: a real error, not an empty fleet
    assert!(fleet::scan_root(tmp_path("missing")).is_err());

    // a campaign whose journal is a directory (scan fails) must not
    // take down the fleet view
    let root = tmp_path("degraded");
    let ok = root.join("good");
    std::fs::create_dir_all(&ok).unwrap();
    {
        let mut j = Journal::open(ok.join("journal.jsonl")).unwrap();
        j.record("campaign_start", 0, vec![]).unwrap();
        j.flush().unwrap();
    }
    let bad = root.join("bad");
    std::fs::create_dir_all(bad.join("journal.jsonl")).unwrap(); // dir, not file!
    // a dir named journal.jsonl is not picked up as a campaign (is_file
    // gate), so this exercises the discovery filter rather than a scan
    // error — both campaigns' dirs exist, only `good` is a campaign
    let view = fleet::scan_root(&root).unwrap();
    assert_eq!(view.campaigns.len(), 1);
    assert_eq!(view.campaigns[0].name, "good");
    assert_eq!(view.campaigns[0].phase(), Phase::Idle);
    std::fs::remove_dir_all(&root).ok();
}
