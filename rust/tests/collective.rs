//! ISSUE-4 test coverage for the compressed gradient collective and
//! the chunk-aligned ZeRO-1 shard layer. No artifacts needed — pure
//! Rust, always runs.
//!
//! Pins:
//! * `collective_fp8 = false` is **bit-identical** to the pinned
//!   serial schedule (`reduce_mean_into_rank0`) at any worker count;
//! * the FP8 path is deterministic across `dp_workers ∈ {1, 2, 4}`
//!   and across thread timing (repeated runs, sizes straddling the
//!   parallel threshold), and equals an independently-computed scalar
//!   serial reference;
//! * quantization error on adversarial (outlier-spiked) gradients is
//!   bounded by the per-chunk auto-scale analysis;
//! * the chunk-aligned owner map and the collective share one chunk
//!   grid, so shard gather/scatter is exact.

use fp8_trainer::coordinator::allreduce::{
    grad_collective, reduce_mean_into_rank0, tree_reduce_sum,
};
use fp8_trainer::fp8::{self, Fp8Format, E4M3, E5M2};
use fp8_trainer::optimizer::{MomentBuffer, MomentStore, ShardLayout};
use fp8_trainer::util::prng::Rng;

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// W gradient replicas with a worker-dependent distribution, sized to
/// cross the parallel fan-out threshold when `n` is large.
fn replicas(seed: u64, w: usize, n: usize) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..w)
        .map(|r| {
            (0..n)
                .map(|_| (rng.normal() as f32) * 0.01 * ((r + 1) as f32))
                .collect()
        })
        .collect()
}

/// Scalar serial reference for the per-chunk FP8 qdq the collective
/// applies to each wire leg: NaN-ignoring amax → pow2 JIT scale →
/// scalar encode/decode (the codec reference the bulk path is pinned
/// against), NaN elements passing through as NaN bytes.
fn qdq_chunks_scalar(fmt: Fp8Format, chunk: usize, buf: &mut [f32]) {
    for c in buf.chunks_mut(chunk) {
        let amax = c.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        let scale = fp8::compute_scale(fmt, amax);
        let max = fmt.max();
        for x in c.iter_mut() {
            let b = if x.is_nan() {
                fp8::encode(fmt, *x)
            } else {
                fp8::encode(fmt, (*x * scale).clamp(-max, max))
            };
            *x = fp8::decode(fmt, b) / scale;
        }
    }
}

#[test]
fn f32_path_is_bit_identical_to_pinned_serial_schedule_at_scale() {
    // large enough that every internal fan-out goes parallel; the f32
    // collective must still be the exact pinned rank-0 reduce
    let n = 200_000;
    for w in [1usize, 2, 4] {
        let mut a = replicas(42, w, n);
        let mut b = replicas(42, w, n);
        grad_collective(&mut a, None, 4096);
        reduce_mean_into_rank0(&mut b);
        assert!(bits_eq(&a[0], &b[0]), "w={w}: collective_fp8=false must be bit-identical");
    }
}

#[test]
fn fp8_path_is_deterministic_across_runs_and_matches_serial_reference() {
    // sizes straddling the parallel threshold (64K elements) plus a
    // ragged chunk tail: thread timing must be invisible, and the
    // parallel result must equal the scalar serial reference exactly
    for fmt in [E4M3, E5M2] {
        for n in [1000usize, 70_000, 200_000] {
            for w in [1usize, 2, 4] {
                let chunk = 4096usize; // ragged: n % chunk != 0 for all n above
                let mut first = replicas(7 + n as u64, w, n);
                let stats1 = grad_collective(&mut first, Some(fmt), chunk);
                for _ in 0..2 {
                    let mut again = replicas(7 + n as u64, w, n);
                    let stats2 = grad_collective(&mut again, Some(fmt), chunk);
                    assert!(
                        bits_eq(&first[0], &again[0]),
                        "{fmt:?} n={n} w={w}: fp8 collective must be bit-reproducible"
                    );
                    assert_eq!(stats1, stats2);
                }
                // independent scalar reference (w=1 skips the wire)
                let mut reference = replicas(7 + n as u64, w, n);
                if w > 1 {
                    for buf in reference.iter_mut() {
                        qdq_chunks_scalar(fmt, chunk, buf);
                    }
                }
                tree_reduce_sum(&mut reference);
                let inv = 1.0 / w as f32;
                for x in reference[0].iter_mut() {
                    *x *= inv;
                }
                if w > 1 {
                    qdq_chunks_scalar(fmt, chunk, &mut reference[0]);
                }
                assert!(
                    bits_eq(&first[0], &reference[0]),
                    "{fmt:?} n={n} w={w}: parallel fp8 path must equal the serial reference"
                );
            }
        }
    }
}

#[test]
fn quantization_error_bounded_on_outlier_spiked_gradients() {
    // adversarial shape: chunks of small-magnitude gradients with one
    // huge outlier spiked into the middle chunk — the per-chunk pow2
    // auto-scale must keep the spike representable (no overflow to
    // NaN/inf) while the error on every element stays inside the
    // format's rounding analysis.
    let chunk = 1000usize;
    let n = 3 * chunk;
    let w = 2usize;
    for fmt in [E4M3, E5M2] {
        let step = 2f32.powi(-(fmt.man_bits() as i32));
        let mk = || -> Vec<Vec<f32>> {
            let mut rng = Rng::new(0xabcd);
            (0..w)
                .map(|_| {
                    let mut g: Vec<f32> =
                        (0..n).map(|_| (rng.normal() as f32) * 1e-3).collect();
                    g[chunk + chunk / 2] = 1e4; // the outlier
                    g
                })
                .collect()
        };
        let workers = mk(); // kept: the bound references per-worker magnitudes
        let mut fp8_bufs = mk();
        let mut f32_bufs = mk();
        grad_collective(&mut fp8_bufs, Some(fmt), chunk);
        grad_collective(&mut f32_bufs, None, chunk);
        for (ci, (qc, xc)) in
            fp8_bufs[0].chunks(chunk).zip(f32_bufs[0].chunks(chunk)).enumerate()
        {
            // per-element bound across both qdq legs. The relative
            // part must reference the PER-WORKER magnitudes: the
            // averaged value can be near zero while each worker's
            // contribution (and so its leg-1 rounding error) is not.
            // Each leg also adds a subnormal floor at the chunk scale
            // (scale ≈ fmt.max() / chunk_amax). Verified against an
            // ml_dtypes reference with >2x margin over 500 seeds.
            let w0 = &workers[0][ci * chunk..(ci + 1) * chunk];
            let w1 = &workers[1][ci * chunk..(ci + 1) * chunk];
            let amax = xc
                .iter()
                .chain(w0)
                .chain(w1)
                .fold(0.0f32, |a, &x| a.max(x.abs()));
            let floor = 4.0 * fmt.min_subnormal() * (amax / fmt.max()).max(1e-12);
            for (i, (&q, &x)) in qc.iter().zip(xc).enumerate() {
                assert!(q.is_finite(), "{fmt:?} chunk {ci} elem {i}: overflowed to {q}");
                let worker_mag = (w0[i].abs() + w1[i].abs()) * 0.5;
                let tol = (worker_mag + x.abs()) * step + floor;
                assert!(
                    (q - x).abs() <= tol,
                    "{fmt:?} chunk {ci} elem {i}: |{q} - {x}| > {tol}"
                );
            }
        }
        // the outlier itself survives at full relative precision
        let q = fp8_bufs[0][chunk + chunk / 2];
        let x = f32_bufs[0][chunk + chunk / 2];
        assert!((q - x).abs() <= x.abs() * step * 2.5, "{fmt:?}: outlier {x} -> {q}");
    }
}

#[test]
fn shard_gather_scatter_roundtrips_on_the_collective_grid() {
    // the owner map and the collective share one absolute chunk grid:
    // scattering a flat buffer into chunk-aligned per-worker
    // MomentBuffer shards and gathering it back must be the identity,
    // with FP8 packing in between (exact mode falls back per chunk
    // when off-grid)
    let chunk = 256usize;
    let total = chunk * 11 + 57; // ragged tail
    let mut rng = Rng::new(99);
    let flat: Vec<f32> = (0..total).map(|_| (rng.normal() as f32) * 2e-3).collect();
    for w in [1usize, 2, 4, 16] {
        let layout = ShardLayout::chunk_aligned(total, w, chunk);
        let mut shards: Vec<MomentBuffer> = layout
            .shards
            .iter()
            .map(|&(_, len)| MomentBuffer::zeros_exact(len, MomentStore::Fp8(E4M3), chunk))
            .collect();
        for (b, &(off, len)) in shards.iter_mut().zip(&layout.shards) {
            b.load_from(&flat[off..off + len]);
            b.pack();
        }
        let mut gathered = Vec::new();
        let mut tmp = Vec::new();
        for b in &shards {
            b.snapshot_into(&mut tmp);
            gathered.extend_from_slice(&tmp);
        }
        assert!(bits_eq(&gathered, &flat), "w={w}: gather(scatter(x)) != x");
        // every chunk has exactly one owner
        for c in 0..total.div_ceil(chunk) {
            let lo = layout.owner_of(c * chunk);
            let hi = layout.owner_of(((c + 1) * chunk - 1).min(total - 1));
            assert_eq!(lo, hi, "w={w}: chunk {c} split across owners");
        }
    }
}

#[test]
fn fp8_collective_propagates_nan_to_the_caller() {
    // a poisoned replica must surface as NaN in the gathered average
    // (the trainer's global-norm clip then skips the update) rather
    // than being silently absorbed by the auto-scale
    let n = 500usize;
    let mut bufs: Vec<Vec<f32>> = (0..2).map(|_| vec![1e-3f32; n]).collect();
    bufs[1][123] = f32::NAN;
    grad_collective(&mut bufs, Some(E5M2), 64);
    assert!(bufs[0][123].is_nan(), "NaN gradient must reach the clip stage");
    assert!(bufs[0][0].is_finite(), "neighbors must stay finite");
}
