//! ISSUE-4/ISSUE-5 test coverage for the compressed gradient
//! collective, the pod-aware two-level topology layer, and the
//! chunk-aligned ZeRO-1 shard layer. No artifacts needed — pure Rust,
//! always runs.
//!
//! Pins:
//! * `collective_fp8_intra = false` is **bit-identical** to the
//!   pinned serial schedule (`reduce_mean_into_rank0`) at any worker
//!   count;
//! * the FP8 path is deterministic across `dp_workers ∈ {1, 2, 4}`
//!   and across thread timing (repeated runs, sizes straddling the
//!   parallel threshold), and equals an independently-computed scalar
//!   serial reference;
//! * the two-level collective at `pods = 1` is bit-identical to the
//!   flat path, the all-f32 two-level schedule is bit-identical to
//!   the flat f32 collective at `pods ∈ {2, 4}` (power-of-two pod
//!   sizes), the two-level FP8 paths are deterministic across reruns
//!   and equal a scalar serial two-level reference, and the default
//!   `intra=f32 / inter=fp8` mix stays inside the quantization bound;
//! * per-leg, per-level wire accounting carries the exact closed-form
//!   totals;
//! * quantization error on adversarial (outlier-spiked) gradients is
//!   bounded by the per-chunk auto-scale analysis;
//! * the chunk-aligned owner map and the collective share one chunk
//!   grid, so shard gather/scatter is exact.

use fp8_trainer::coordinator::allreduce::{
    grad_collective, reduce_mean_into_rank0, tree_reduce_sum, CollectiveStats,
};
use fp8_trainer::coordinator::topology::{hier_grad_collective, PodTopology};
use fp8_trainer::fp8::{self, Fp8Format, E4M3, E5M2};
use fp8_trainer::optimizer::{MomentBuffer, MomentStore, ShardLayout};
use fp8_trainer::util::prng::Rng;

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// W gradient replicas with a worker-dependent distribution, sized to
/// cross the parallel fan-out threshold when `n` is large.
fn replicas(seed: u64, w: usize, n: usize) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..w)
        .map(|r| {
            (0..n)
                .map(|_| (rng.normal() as f32) * 0.01 * ((r + 1) as f32))
                .collect()
        })
        .collect()
}

/// Scalar serial reference for the per-chunk FP8 qdq the collective
/// applies to each wire leg: NaN-ignoring amax → pow2 JIT scale →
/// scalar encode/decode (the codec reference the bulk path is pinned
/// against), NaN elements passing through as NaN bytes.
fn qdq_chunks_scalar(fmt: Fp8Format, chunk: usize, buf: &mut [f32]) {
    for c in buf.chunks_mut(chunk) {
        let amax = c.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        let scale = fp8::compute_scale(fmt, amax);
        let max = fmt.max();
        for x in c.iter_mut() {
            let b = if x.is_nan() {
                fp8::encode(fmt, *x)
            } else {
                fp8::encode(fmt, (*x * scale).clamp(-max, max))
            };
            *x = fp8::decode(fmt, b) / scale;
        }
    }
}

#[test]
fn f32_path_is_bit_identical_to_pinned_serial_schedule_at_scale() {
    // large enough that every internal fan-out goes parallel; the f32
    // collective must still be the exact pinned rank-0 reduce
    let n = 200_000;
    for w in [1usize, 2, 4] {
        let mut a = replicas(42, w, n);
        let mut b = replicas(42, w, n);
        grad_collective(&mut a, None, 4096);
        reduce_mean_into_rank0(&mut b);
        assert!(bits_eq(&a[0], &b[0]), "w={w}: uncompressed collective must be bit-identical");
    }
}

#[test]
fn fp8_path_is_deterministic_across_runs_and_matches_serial_reference() {
    // sizes straddling the parallel threshold (64K elements) plus a
    // ragged chunk tail: thread timing must be invisible, and the
    // parallel result must equal the scalar serial reference exactly
    for fmt in [E4M3, E5M2] {
        for n in [1000usize, 70_000, 200_000] {
            for w in [1usize, 2, 4] {
                let chunk = 4096usize; // ragged: n % chunk != 0 for all n above
                let mut first = replicas(7 + n as u64, w, n);
                let stats1 = grad_collective(&mut first, Some(fmt), chunk);
                for _ in 0..2 {
                    let mut again = replicas(7 + n as u64, w, n);
                    let stats2 = grad_collective(&mut again, Some(fmt), chunk);
                    assert!(
                        bits_eq(&first[0], &again[0]),
                        "{fmt:?} n={n} w={w}: fp8 collective must be bit-reproducible"
                    );
                    assert_eq!(stats1, stats2);
                }
                // independent scalar reference (w=1 skips the wire)
                let mut reference = replicas(7 + n as u64, w, n);
                if w > 1 {
                    for buf in reference.iter_mut() {
                        qdq_chunks_scalar(fmt, chunk, buf);
                    }
                }
                tree_reduce_sum(&mut reference);
                let inv = 1.0 / w as f32;
                for x in reference[0].iter_mut() {
                    *x *= inv;
                }
                if w > 1 {
                    qdq_chunks_scalar(fmt, chunk, &mut reference[0]);
                }
                assert!(
                    bits_eq(&first[0], &reference[0]),
                    "{fmt:?} n={n} w={w}: parallel fp8 path must equal the serial reference"
                );
            }
        }
    }
}

#[test]
fn quantization_error_bounded_on_outlier_spiked_gradients() {
    // adversarial shape: chunks of small-magnitude gradients with one
    // huge outlier spiked into the middle chunk — the per-chunk pow2
    // auto-scale must keep the spike representable (no overflow to
    // NaN/inf) while the error on every element stays inside the
    // format's rounding analysis.
    let chunk = 1000usize;
    let n = 3 * chunk;
    let w = 2usize;
    for fmt in [E4M3, E5M2] {
        let step = 2f32.powi(-(fmt.man_bits() as i32));
        let mk = || -> Vec<Vec<f32>> {
            let mut rng = Rng::new(0xabcd);
            (0..w)
                .map(|_| {
                    let mut g: Vec<f32> =
                        (0..n).map(|_| (rng.normal() as f32) * 1e-3).collect();
                    g[chunk + chunk / 2] = 1e4; // the outlier
                    g
                })
                .collect()
        };
        let workers = mk(); // kept: the bound references per-worker magnitudes
        let mut fp8_bufs = mk();
        let mut f32_bufs = mk();
        grad_collective(&mut fp8_bufs, Some(fmt), chunk);
        grad_collective(&mut f32_bufs, None, chunk);
        for (ci, (qc, xc)) in
            fp8_bufs[0].chunks(chunk).zip(f32_bufs[0].chunks(chunk)).enumerate()
        {
            // per-element bound across both qdq legs. The relative
            // part must reference the PER-WORKER magnitudes: the
            // averaged value can be near zero while each worker's
            // contribution (and so its leg-1 rounding error) is not.
            // Each leg also adds a subnormal floor at the chunk scale
            // (scale ≈ fmt.max() / chunk_amax). Verified against an
            // ml_dtypes reference with >2x margin over 500 seeds.
            let w0 = &workers[0][ci * chunk..(ci + 1) * chunk];
            let w1 = &workers[1][ci * chunk..(ci + 1) * chunk];
            let amax = xc
                .iter()
                .chain(w0)
                .chain(w1)
                .fold(0.0f32, |a, &x| a.max(x.abs()));
            let floor = 4.0 * fmt.min_subnormal() * (amax / fmt.max()).max(1e-12);
            for (i, (&q, &x)) in qc.iter().zip(xc).enumerate() {
                assert!(q.is_finite(), "{fmt:?} chunk {ci} elem {i}: overflowed to {q}");
                let worker_mag = (w0[i].abs() + w1[i].abs()) * 0.5;
                let tol = (worker_mag + x.abs()) * step + floor;
                assert!(
                    (q - x).abs() <= tol,
                    "{fmt:?} chunk {ci} elem {i}: |{q} - {x}| > {tol}"
                );
            }
        }
        // the outlier itself survives at full relative precision
        let q = fp8_bufs[0][chunk + chunk / 2];
        let x = f32_bufs[0][chunk + chunk / 2];
        assert!((q - x).abs() <= x.abs() * step * 2.5, "{fmt:?}: outlier {x} -> {q}");
    }
}

#[test]
fn shard_gather_scatter_roundtrips_on_the_collective_grid() {
    // the owner map and the collective share one absolute chunk grid:
    // scattering a flat buffer into chunk-aligned per-worker
    // MomentBuffer shards and gathering it back must be the identity,
    // with FP8 packing in between (exact mode falls back per chunk
    // when off-grid)
    let chunk = 256usize;
    let total = chunk * 11 + 57; // ragged tail
    let mut rng = Rng::new(99);
    let flat: Vec<f32> = (0..total).map(|_| (rng.normal() as f32) * 2e-3).collect();
    for w in [1usize, 2, 4, 16] {
        let layout = ShardLayout::chunk_aligned(total, w, chunk);
        let mut shards: Vec<MomentBuffer> = layout
            .shards
            .iter()
            .map(|&(_, len)| MomentBuffer::zeros_exact(len, MomentStore::Fp8(E4M3), chunk))
            .collect();
        for (b, &(off, len)) in shards.iter_mut().zip(&layout.shards) {
            b.load_from(&flat[off..off + len]);
            b.pack();
        }
        let mut gathered = Vec::new();
        let mut tmp = Vec::new();
        for b in &shards {
            b.snapshot_into(&mut tmp);
            gathered.extend_from_slice(&tmp);
        }
        assert!(bits_eq(&gathered, &flat), "w={w}: gather(scatter(x)) != x");
        // every chunk has exactly one owner
        for c in 0..total.div_ceil(chunk) {
            let lo = layout.owner_of(c * chunk);
            let hi = layout.owner_of(((c + 1) * chunk - 1).min(total - 1));
            assert_eq!(lo, hi, "w={w}: chunk {c} split across owners");
        }
    }
}

/// Scalar serial reference for the full two-level collective: the
/// same pipeline as `topology::hier_grad_collective` but with every
/// qdq done by the scalar codec reference and the pod/leader sums
/// done on *contiguous* buffer sets (an independent realization of
/// the strided leader tree). Returns the gathered average.
fn hier_reference(
    mut workers: Vec<Vec<f32>>,
    pods: usize,
    fmt_intra: Option<Fp8Format>,
    fmt_inter: Option<Fp8Format>,
    chunk: usize,
) -> Vec<f32> {
    let w = workers.len();
    let p = w / pods;
    assert_eq!(p * pods, w);
    if let Some(fmt) = fmt_intra {
        for b in workers.iter_mut() {
            qdq_chunks_scalar(fmt, chunk, b);
        }
    }
    // per-pod sums on contiguous slices
    let mut leaders: Vec<Vec<f32>> = Vec::with_capacity(pods);
    for pod in 0..pods {
        tree_reduce_sum(&mut workers[pod * p..(pod + 1) * p]);
        leaders.push(workers[pod * p].clone());
    }
    if let Some(fmt) = fmt_inter {
        for b in leaders.iter_mut() {
            qdq_chunks_scalar(fmt, chunk, b);
        }
    }
    // the leader exchange as a contiguous tree — independent of the
    // strided in-place tree the library uses
    tree_reduce_sum(&mut leaders);
    let inv = 1.0 / w as f32;
    let mut out = leaders.swap_remove(0);
    for x in out.iter_mut() {
        *x *= inv;
    }
    if let Some(fmt) = fmt_inter {
        qdq_chunks_scalar(fmt, chunk, &mut out);
    }
    if let Some(fmt) = fmt_intra {
        qdq_chunks_scalar(fmt, chunk, &mut out);
    }
    out
}

#[test]
fn hier_pods1_is_bit_identical_to_flat_path() {
    // pods = 1 must be the flat collective — same bits, same stats —
    // in every compression mode (inter setting is irrelevant: there
    // is no inter level)
    let n = 70_000; // crosses the parallel fan-out threshold
    let chunk = 4096;
    for w in [1usize, 2, 4] {
        for intra in [None, Some(E4M3), Some(E5M2)] {
            let topo = PodTopology::new(w, 1).unwrap();
            let mut a = replicas(5, w, n);
            let mut b = replicas(5, w, n);
            let sa = hier_grad_collective(&mut a, topo, intra, Some(E5M2), chunk);
            let sb = grad_collective(&mut b, intra, chunk);
            assert!(bits_eq(&a[0], &b[0]), "w={w} intra={intra:?}");
            assert_eq!(sa, sb, "stats must match the flat accounting exactly");
        }
    }
}

#[test]
fn hier_f32_two_level_is_bit_identical_to_flat_f32() {
    // with compression off on both levels, the two-level schedule at
    // power-of-two pod sizes is the SAME summation tree as the flat
    // collective (the flat binary tree decomposes at pod boundaries
    // when workers_per_pod = 2^k), so the result is bit-identical —
    // topology moves bytes, not additions. Large n so every internal
    // fan-out goes parallel.
    let n = 200_000;
    for (w, pods_set) in [(4usize, vec![2usize, 4]), (8, vec![2, 4])] {
        for pods in pods_set {
            let topo = PodTopology::new(w, pods).unwrap();
            let mut a = replicas(42, w, n);
            let mut b = replicas(42, w, n);
            let s = hier_grad_collective(&mut a, topo, None, None, 4096);
            reduce_mean_into_rank0(&mut b);
            assert!(
                bits_eq(&a[0], &b[0]),
                "w={w} pods={pods}: f32 two-level must be bit-identical to flat"
            );
            // and the executed bytes are all-f32 on both levels
            assert_eq!(s.wire_bytes(), s.wire_bytes_f32());
        }
    }
}

#[test]
fn hier_fp8_two_level_is_deterministic_and_matches_serial_reference() {
    // sizes straddling the parallel threshold, ragged chunk tails,
    // pods ∈ {2, 4}: reruns must be bit-identical (thread timing is
    // invisible) and equal the scalar serial two-level reference
    let chunk = 4096usize;
    for fmt in [E4M3, E5M2] {
        for n in [1000usize, 70_000] {
            for pods in [2usize, 4] {
                let w = 8usize;
                let topo = PodTopology::new(w, pods).unwrap();
                let mut first = replicas(100 + n as u64, w, n);
                let s1 = hier_grad_collective(&mut first, topo, Some(fmt), Some(fmt), chunk);
                for _ in 0..2 {
                    let mut again = replicas(100 + n as u64, w, n);
                    let s2 = hier_grad_collective(&mut again, topo, Some(fmt), Some(fmt), chunk);
                    assert!(
                        bits_eq(&first[0], &again[0]),
                        "{fmt:?} n={n} pods={pods}: two-level fp8 must be bit-reproducible"
                    );
                    assert_eq!(s1, s2);
                }
                let fresh = replicas(100 + n as u64, w, n);
                let reference = hier_reference(fresh, pods, Some(fmt), Some(fmt), chunk);
                assert!(
                    bits_eq(&first[0], &reference),
                    "{fmt:?} n={n} pods={pods}: must equal the scalar serial reference"
                );
            }
        }
    }
}

#[test]
fn hier_mixed_intra_f32_inter_fp8_matches_reference_and_quantization_bound() {
    // the default topology mix: f32 on the fat intra-pod links, FP8
    // on the thin inter-pod pipe. Must (a) equal the scalar serial
    // reference bit-for-bit, and (b) stay inside the two-leg
    // per-chunk auto-scale bound against the all-f32 result — the
    // relative part references the POD-PARTIAL magnitudes (the values
    // the inter legs actually quantize), mirroring the per-worker
    // bound of the flat test (validated against an ml_dtypes
    // simulation; see rust/EXPERIMENTS.md §Topology).
    let chunk = 1000usize;
    let n = 3 * chunk;
    let (w, pods) = (8usize, 2usize);
    let p = w / pods;
    for fmt in [E4M3, E5M2] {
        let step = 2f32.powi(-(fmt.man_bits() as i32));
        let mk = || replicas(0xbeef + fmt.man_bits() as u64, w, n);

        let mut mixed = mk();
        let topo = PodTopology::new(w, pods).unwrap();
        hier_grad_collective(&mut mixed, topo, None, Some(fmt), chunk);
        let reference = hier_reference(mk(), pods, None, Some(fmt), chunk);
        assert!(bits_eq(&mixed[0], &reference), "{fmt:?}: must equal the serial reference");

        // pod partial sums (exact: no intra quantization in this mix)
        let mut partials = mk();
        let mut pods_sums: Vec<Vec<f32>> = Vec::new();
        for pod in 0..pods {
            tree_reduce_sum(&mut partials[pod * p..(pod + 1) * p]);
            pods_sums.push(partials[pod * p].clone());
        }
        let mut flat = mk();
        reduce_mean_into_rank0(&mut flat);

        for (ci, (qc, xc)) in mixed[0].chunks(chunk).zip(flat[0].chunks(chunk)).enumerate() {
            let s0 = &pods_sums[0][ci * chunk..(ci + 1) * chunk];
            let s1 = &pods_sums[1][ci * chunk..(ci + 1) * chunk];
            let amax = xc
                .iter()
                .chain(s0)
                .chain(s1)
                .fold(0.0f32, |a, &x| a.max(x.abs()));
            let floor = 4.0 * fmt.min_subnormal() * (amax / fmt.max()).max(1e-12);
            for (i, (&q, &x)) in qc.iter().zip(xc).enumerate() {
                assert!(q.is_finite(), "{fmt:?} chunk {ci} elem {i}: non-finite {q}");
                // leg 1 rounds each pod partial (error ∝ |s_p|·step,
                // scaled by 1/W in the mean), leg 2 rounds the mean
                let partial_mag = (s0[i].abs() + s1[i].abs()) / w as f32;
                let tol = (partial_mag + x.abs()) * step + floor;
                assert!(
                    (q - x).abs() <= tol,
                    "{fmt:?} chunk {ci} elem {i}: |{q} - {x}| > {tol}"
                );
            }
        }
    }
}

#[test]
fn hier_wire_stats_split_by_level_and_leg() {
    // the per-level split must carry exact closed forms — and the
    // default mix must show up as "intra at f32 ratio, inter < 0.3"
    let n = 10_000usize;
    let chunk = 256usize;
    let n_chunks = n.div_ceil(chunk) as u64;
    let (w, pods) = (8usize, 4usize);
    let p = (w / pods) as u64;
    let topo = PodTopology::new(w, pods).unwrap();
    let mut bufs = replicas(9, w, n);
    let s = hier_grad_collective(&mut bufs, topo, None, Some(E5M2), chunk);
    assert_eq!(s.elems, n);
    let intra_leg = pods as u64 * (p - 1) * n as u64 * 4;
    assert_eq!(s.intra.reduce_scatter, intra_leg);
    assert_eq!(s.intra.all_gather, intra_leg);
    assert_eq!(s.intra, s.intra_f32, "uncompressed intra must equal its f32 baseline");
    let inter_leg = (pods as u64 - 1) * (n as u64 + 4 * n_chunks);
    assert_eq!(s.inter.reduce_scatter, inter_leg);
    assert_eq!(s.inter.all_gather, inter_leg);
    assert_eq!(s.inter_f32.reduce_scatter, (pods as u64 - 1) * n as u64 * 4);
    assert!(s.inter_wire_ratio() < 0.3, "inter ratio {}", s.inter_wire_ratio());
    assert_eq!(s.wire_bytes(), 2 * (intra_leg + inter_leg));
    // stats are plain data: the default is all-zero except elems
    assert_eq!(CollectiveStats::default().wire_bytes(), 0);
}

#[test]
fn fp8_collective_propagates_nan_to_the_caller() {
    // a poisoned replica must surface as NaN in the gathered average
    // (the trainer's global-norm clip then skips the update) rather
    // than being silently absorbed by the auto-scale
    let n = 500usize;
    let mut bufs: Vec<Vec<f32>> = (0..2).map(|_| vec![1e-3f32; n]).collect();
    bufs[1][123] = f32::NAN;
    grad_collective(&mut bufs, Some(E5M2), 64);
    assert!(bufs[0][123].is_nan(), "NaN gradient must reach the clip stage");
    assert!(bufs[0][0].is_finite(), "neighbors must stay finite");

    // same transparency through the two-level path: a poisoned member
    // of pod 1 must surface in the gathered average
    let mut bufs: Vec<Vec<f32>> = (0..4).map(|_| vec![1e-3f32; n]).collect();
    bufs[3][77] = f32::NAN;
    let topo = PodTopology::new(4, 2).unwrap();
    hier_grad_collective(&mut bufs, topo, Some(E4M3), Some(E5M2), 64);
    assert!(bufs[0][77].is_nan(), "NaN must survive both levels");
    assert!(bufs[0][0].is_finite(), "neighbors must stay finite");
}
