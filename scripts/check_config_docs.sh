#!/usr/bin/env bash
# Docs gate: every config key the loader accepts must be documented in
# docs/OPERATIONS.md (the operator's single reference table).
#
# Key sources scanned:
#   * rust/src/config/mod.rs — the `match k.as_str()` arms of
#     TrainConfig::from_kv (both bare and dotted spellings);
#   * rust/src/bin/campaign.rs — the CLI-only session keys
#     (`k == "stop_after"`-style comparisons).
#
# A key counts as documented when it appears backticked (`key`) in
# docs/OPERATIONS.md — backticks prevent substring false-passes (`lr`
# inside `min_lr_frac`). Exit non-zero listing every undocumented key.
#
# Pure POSIX shell + grep/sed — no toolchain needed, so this gate runs
# unconditionally in scripts/verify.sh and the CI docs job.

set -euo pipefail
cd "$(dirname "$0")/.."

DOC=docs/OPERATIONS.md
CFG=rust/src/config/mod.rs
CLI=rust/src/bin/campaign.rs

for f in "$DOC" "$CFG" "$CLI"; do
  if [ ! -f "$f" ]; then
    echo "check_config_docs: missing $f" >&2
    exit 1
  fi
done

# Key literals from the from_kv match arms (range ends at the
# catch-all `_ =>`); error-message strings contain spaces/braces and
# never match the token pattern.
keys=$(
  {
    sed -n '/match k.as_str() {/,/_ =>/p' "$CFG" | grep -oE '"[a-z0-9_.]+"'
    grep -oE 'k == "[a-z0-9_]+"' "$CLI" | grep -oE '"[a-z0-9_]+"'
  } | tr -d '"' | sort -u
)

if [ -z "$keys" ]; then
  echo "check_config_docs: extracted no keys — loader layout changed?" >&2
  echo "  (expected a 'match k.as_str()' block in $CFG)" >&2
  exit 1
fi

missing=0
for k in $keys; do
  if ! grep -qF "\`$k\`" "$DOC"; then
    echo "UNDOCUMENTED config key: $k — add it to $DOC" >&2
    missing=1
  fi
done

if [ "$missing" -ne 0 ]; then
  echo "check_config_docs: FAIL (see keys above)" >&2
  exit 1
fi
echo "check_config_docs: OK ($(echo "$keys" | wc -l | tr -d ' ') key spellings documented in $DOC)"
