#!/usr/bin/env bash
# Tier-1 verify path for fp8-trainer.
#
# Steps:
#   1. release build
#   2. test suite (unit + property + campaign; artifact-gated tests
#      skip themselves with a note on a bare checkout)
#   3. rustdoc gate: `cargo doc --no-deps` must be warning-clean —
#      broken intra-doc links and bad codeblock attributes are
#      promoted to errors, so a rustdoc regression fails tier-1.
#
# Run from the repo root: scripts/verify.sh

set -euo pipefail
cd "$(dirname "$0")/.."

echo "== [1/3] cargo build --release"
cargo build --release

echo "== [2/3] cargo test -q"
cargo test -q

echo "== [3/3] cargo doc --no-deps (doc-link gate)"
# -W unused: rustdoc's own unused-lint pass stays advisory; the doc
# correctness lints below are the gate.
RUSTDOCFLAGS="${RUSTDOCFLAGS:-} \
  -D rustdoc::broken-intra-doc-links \
  -D rustdoc::invalid-codeblock-attributes \
  -D rustdoc::invalid-rust-codeblocks \
  -D rustdoc::bare-urls" \
  cargo doc --no-deps

echo "verify: OK"
