#!/usr/bin/env bash
# Tier-1 verify path for fp8-trainer — the single local entry point for
# the same gates CI runs (.github/workflows/ci.yml).
#
# Steps:
#   1. release build
#   2. test suite (unit + property + collective + campaign + gemm;
#      artifact-gated tests skip themselves with a note on a bare
#      checkout)
#   3. rustdoc gate: `cargo doc --no-deps` must be warning-clean —
#      broken intra-doc links and bad codeblock attributes are
#      promoted to errors, so a rustdoc regression fails tier-1.
#   4. rustfmt gate: `cargo fmt --check` (skipped with a note when the
#      rustfmt component is not installed)
#   5. clippy gate: `cargo clippy --all-targets -- -D warnings`
#      (skipped with a note when the clippy component is not installed)
#   6. config-docs gate: every config key the loader accepts must be
#      documented in docs/OPERATIONS.md
#      (scripts/check_config_docs.sh — pure shell, always runs)
#   7. journal-docs gate: every journal event kind the campaign can
#      emit must have a runbook row in docs/OPERATIONS.md AND a
#      field-by-field schema row in docs/JOURNAL.md
#      (scripts/check_journal_docs.sh — pure shell, always runs)
#   8. worker-loss drill: kill a W=4/pods=2 campaign mid-run, resume
#      with `--reshard` on W=3/pods=1 through the real CLI, demand a
#      bit-identical final loss + journaled reshard
#      (scripts/drill_worker_loss.sh — self-skips on bare checkouts)
#
# VERIFY_SKIP_LINT=1 skips steps 4/5 — CI sets it in the verify job so
# fmt/clippy run exactly once, in the dedicated lint job.
#
# Run from the repo root: scripts/verify.sh

set -euo pipefail
cd "$(dirname "$0")/.."

echo "== [1/8] cargo build --release"
cargo build --release

echo "== [2/8] cargo test -q"
cargo test -q

echo "== [3/8] cargo doc --no-deps (doc-link gate)"
# -W unused: rustdoc's own unused-lint pass stays advisory; the doc
# correctness lints below are the gate.
RUSTDOCFLAGS="${RUSTDOCFLAGS:-} \
  -D rustdoc::broken-intra-doc-links \
  -D rustdoc::invalid-codeblock-attributes \
  -D rustdoc::invalid-rust-codeblocks \
  -D rustdoc::bare-urls" \
  cargo doc --no-deps

echo "== [4/8] cargo fmt --check"
if [ "${VERIFY_SKIP_LINT:-0}" = "1" ]; then
  echo "  [skip] VERIFY_SKIP_LINT=1 (CI runs fmt/clippy in the lint job)"
elif cargo fmt --version >/dev/null 2>&1; then
  cargo fmt --check
else
  echo "  [skip] rustfmt component not installed (rustup component add rustfmt)"
fi

echo "== [5/8] cargo clippy --all-targets -- -D warnings"
if [ "${VERIFY_SKIP_LINT:-0}" = "1" ]; then
  echo "  [skip] VERIFY_SKIP_LINT=1 (CI runs fmt/clippy in the lint job)"
elif cargo clippy --version >/dev/null 2>&1; then
  cargo clippy --all-targets -- -D warnings
else
  echo "  [skip] clippy component not installed (rustup component add clippy)"
fi

echo "== [6/8] config-key docs coverage (docs/OPERATIONS.md)"
scripts/check_config_docs.sh

echo "== [7/8] journal-event docs coverage (docs/OPERATIONS.md + docs/JOURNAL.md)"
scripts/check_journal_docs.sh

echo "== [8/8] worker-loss reshard drill (self-skips on bare checkouts)"
scripts/drill_worker_loss.sh

echo "verify: OK"
