#!/usr/bin/env bash
# Docs gate: every journal event kind the campaign subsystem can emit
# must be documented twice over —
#   1. a runbook row in docs/OPERATIONS.md (the journal event
#      reference): an operator reading a journal line should never
#      meet an event the runbook does not explain;
#   2. a field-by-field schema row in docs/JOURNAL.md (the normative
#      format spec): a `| `kind` |` table row, so every kind's fields
#      and semantics are specified, not just mentioned.
# A new event kind missing either fails CI.
#
# Kind sources scanned: every `.record("<kind>"` call site under
# rust/src/campaign/ and rust/src/bin/ (the journal's only producers).
# The call spans lines in rustfmt output, so files are flattened before
# matching. A kind counts as runbook-documented when it appears
# backticked (`kind`) anywhere in docs/OPERATIONS.md, and as
# spec-documented when docs/JOURNAL.md has a table row starting
# "| `kind` |".
#
# Pure POSIX shell + grep/sed/tr — no toolchain needed, so this gate
# runs unconditionally in scripts/verify.sh and the CI docs job.

set -euo pipefail
cd "$(dirname "$0")/.."

DOC=docs/OPERATIONS.md
SPEC=docs/JOURNAL.md

for f in "$DOC" "$SPEC"; do
  if [ ! -f "$f" ]; then
    echo "check_journal_docs: missing $f" >&2
    exit 1
  fi
done

kinds=$(
  for f in rust/src/campaign/*.rs rust/src/bin/*.rs; do
    tr '\n' ' ' <"$f"
  done |
    grep -oE '\.record\(\s*"[a-z_]+"' |
    grep -oE '"[a-z_]+"' | tr -d '"' | sort -u
)

# Sanity floor: the subsystem emits many kinds (12 as of the streaming
# journal); extracting almost none means the call-site pattern
# drifted, which must fail loudly rather than silently gate nothing.
n=$(echo "$kinds" | grep -c . || true)
if [ "$n" -lt 10 ]; then
  echo "check_journal_docs: extracted only $n event kind(s) — did the" >&2
  echo "  Journal::record call-site pattern change? (expected >= 10)" >&2
  exit 1
fi

missing=0
for k in $kinds; do
  if ! grep -qF "\`$k\`" "$DOC"; then
    echo "UNDOCUMENTED journal event kind: $k — add a runbook row to $DOC" >&2
    missing=1
  fi
  if ! grep -qE "^\| \`$k\` \|" "$SPEC"; then
    echo "UNSPECIFIED journal event kind: $k — add a schema row (| \`$k\` | ...) to $SPEC" >&2
    missing=1
  fi
done

if [ "$missing" -ne 0 ]; then
  echo "check_journal_docs: FAIL (see kinds above)" >&2
  exit 1
fi
echo "check_journal_docs: OK ($n event kinds documented in $DOC + schema rows in $SPEC)"
