#!/usr/bin/env bash
# Worker-loss drill: the end-to-end elastic-resharding exercise through
# the real `campaign` CLI — the operational twin of the
# `worker_loss_drill_reshard_resumes_bit_exact` test.
#
# Scenario:
#   1. reference campaign runs uninterrupted on W=4 / pods=2;
#   2. the drill campaign runs on the same topology but is "killed"
#      (orderly pause) at step 4;
#   3. a worker is lost: `campaign resume --reshard dp_workers=3
#      pods=1` continues it on the shrunken fleet;
#   4. the final loss must be BIT-identical to the reference run's, the
#      `reshard` event must be journaled, and `campaign status` must
#      show the topology history.
#
# Self-skips (exit 0 with a note) on a bare checkout: no cargo, or no
# artifacts/ directory — same convention as the artifact-gated tests.
#
# Run from the repo root: scripts/drill_worker_loss.sh

set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
  echo "drill_worker_loss: [skip] cargo not installed"
  exit 0
fi
ARTIFACTS="${FP8_ARTIFACTS:-artifacts}"
if [ ! -d "$ARTIFACTS" ]; then
  echo "drill_worker_loss: [skip] $ARTIFACTS/ not found (run \`make artifacts\` first)"
  exit 0
fi

cargo build --release --bin campaign
BIN=target/release/campaign

WORK=$(mktemp -d "${TMPDIR:-/tmp}/fp8_worker_loss_drill.XXXXXX")
trap 'rm -rf "$WORK"' EXIT

COMMON="size=tiny recipe=fp8_full steps=10 warmup_steps=2 lr=1e-3 snapshot_every=3"
FULL="dp_workers=4 pods=2"

echo "== reference: uninterrupted campaign on W=4/pods=2"
"$BIN" run --dir "$WORK/ref" $COMMON $FULL | tee "$WORK/ref.out"

echo "== drill: same campaign, killed at step 4"
"$BIN" run --dir "$WORK/drill" $COMMON $FULL stop_after=4

echo "== worker lost: resume --reshard on W=3/pods=1"
"$BIN" resume --dir "$WORK/drill" --reshard $COMMON dp_workers=3 pods=1 |
  tee "$WORK/drill.out"

# bit-exactness: the journal's `complete` event records final_loss via
# the shortest-roundtrip f64 emitter, so string equality here IS bit
# equality of the final loss across the two topologies
ref_loss=$(grep -o '"final_loss":[^,}]*' "$WORK/ref/journal.jsonl" | tail -1)
drill_loss=$(grep -o '"final_loss":[^,}]*' "$WORK/drill/journal.jsonl" | tail -1)
if [ -z "$ref_loss" ] || [ "$ref_loss" != "$drill_loss" ]; then
  echo "drill_worker_loss: FAIL — final loss diverged ('$ref_loss' vs '$drill_loss')" >&2
  exit 1
fi

# the reshard must be on the journal and visible in status
if ! grep -q '"event":"reshard"' "$WORK/drill/journal.jsonl"; then
  echo "drill_worker_loss: FAIL — no reshard event in the journal" >&2
  exit 1
fi
"$BIN" status --dir "$WORK/drill" | tee "$WORK/status.out"
if ! grep -q 'topology history' "$WORK/status.out"; then
  echo "drill_worker_loss: FAIL — \`campaign status\` does not show the topology history" >&2
  exit 1
fi

echo "drill_worker_loss: OK (resharded campaign matched the reference: $drill_loss)"
